//! CRC-32 (IEEE 802.3, the zlib/PNG polynomial), table-driven.
//!
//! Used as the integrity footer of checkpoint format v2: the
//! atomic-rename protocol (see [`super::fsio`]) prevents *torn* files,
//! but not silent corruption at rest (bit rot, bad sectors, truncation
//! by a foreign tool).  A 4-byte CRC over the whole payload rejects any
//! single-bit — and overwhelmingly any multi-bit — corruption.

const fn make_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = make_table();

/// CRC-32 of `data` (init `0xFFFFFFFF`, reflected, final xor).
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(data);
    c.finish()
}

/// Incremental CRC-32 with the same parameters as [`crc32`]: feeding a
/// byte stream chunk by chunk through [`Crc32::update`] yields exactly
/// the one-shot digest of the concatenation.
///
/// Needed by the shard reader (`data/shard`), which must verify the
/// footer of multi-gigabyte files without holding them in memory.
#[derive(Debug, Clone)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    pub fn new() -> Self {
        Self { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, data: &[u8]) {
        let mut c = self.state;
        for &b in data {
            c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The canonical CRC-32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"allpairs"), crc32(b"allpairs"));
    }

    #[test]
    fn incremental_matches_one_shot_at_every_split() {
        let data: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        let want = crc32(&data);
        for split in [0, 1, 7, 499, 999, 1000] {
            let mut c = Crc32::new();
            c.update(&data[..split]);
            c.update(&data[split..]);
            assert_eq!(c.finish(), want, "split at {split}");
        }
        let mut byte_at_a_time = Crc32::new();
        for b in &data {
            byte_at_a_time.update(std::slice::from_ref(b));
        }
        assert_eq!(byte_at_a_time.finish(), want);
    }

    #[test]
    fn detects_single_bit_flips() {
        let base = b"the quick brown fox jumps over the lazy dog".to_vec();
        let want = crc32(&base);
        for i in 0..base.len() {
            for bit in 0..8 {
                let mut flipped = base.clone();
                flipped[i] ^= 1 << bit;
                assert_ne!(crc32(&flipped), want, "flip at byte {i} bit {bit}");
            }
        }
    }
}
