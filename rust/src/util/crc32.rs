//! CRC-32 (IEEE 802.3, the zlib/PNG polynomial), table-driven.
//!
//! Used as the integrity footer of checkpoint format v2: the
//! atomic-rename protocol (see [`super::fsio`]) prevents *torn* files,
//! but not silent corruption at rest (bit rot, bad sectors, truncation
//! by a foreign tool).  A 4-byte CRC over the whole payload rejects any
//! single-bit — and overwhelmingly any multi-bit — corruption.

const fn make_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = make_table();

/// CRC-32 of `data` (init `0xFFFFFFFF`, reflected, final xor).
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The canonical CRC-32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"allpairs"), crc32(b"allpairs"));
    }

    #[test]
    fn detects_single_bit_flips() {
        let base = b"the quick brown fox jumps over the lazy dog".to_vec();
        let want = crc32(&base);
        for i in 0..base.len() {
            for bit in 0..8 {
                let mut flipped = base.clone();
                flipped[i] ^= 1 << bit;
                assert_ne!(crc32(&flipped), want, "flip at byte {i} bit {bit}");
            }
        }
    }
}
