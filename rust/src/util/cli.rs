//! Minimal declarative CLI argument parser (clap stand-in).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional
//! subcommands, defaults and `--help` text generation — exactly what the
//! `allpairs` binary and examples need, nothing more.

use std::collections::BTreeMap;

/// Parsed arguments: subcommand + options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First positional token (subcommand), if any.
    pub command: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args` (skipping argv[0]).
    pub fn from_env() -> anyhow::Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse an iterator of tokens.
    pub fn parse(tokens: impl IntoIterator<Item = String>) -> anyhow::Result<Self> {
        let mut args = Args::default();
        let mut iter = tokens.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                anyhow::ensure!(!rest.is_empty(), "bare '--' not supported");
                if let Some((k, v)) = rest.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.opts.insert(rest.to_string(), v);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                anyhow::bail!("unexpected positional argument {tok:?}");
            }
        }
        Ok(args)
    }

    /// Boolean flag (`--smoke`).
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// String option with default.
    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.opts
            .get(name)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    /// Optional string option.
    pub fn get_opt(&self, name: &str) -> Option<String> {
        self.opts.get(name).cloned()
    }

    /// Typed option with default.
    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name} {v:?}: {e}")),
        }
    }

    /// All `--key value` options seen (for validation).
    pub fn option_names(&self) -> impl Iterator<Item = &str> {
        self.opts.keys().map(|s| s.as_str())
    }

    /// Reject unknown options — typo protection for long sweep commands.
    pub fn expect_known(&self, known: &[&str]) -> anyhow::Result<()> {
        for name in self.option_names().chain(self.flags.iter().map(|s| s.as_str())) {
            anyhow::ensure!(
                known.contains(&name),
                "unknown option --{name} (known: {})",
                known.join(", ")
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> impl Iterator<Item = String> + '_ {
        s.split_whitespace().map(|s| s.to_string())
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(toks("sweep --epochs 5 --smoke --out=results")).unwrap();
        assert_eq!(a.command.as_deref(), Some("sweep"));
        assert_eq!(a.get("epochs", 0usize).unwrap(), 5);
        assert!(a.flag("smoke"));
        assert_eq!(a.get_str("out", "x"), "results");
        assert!(!a.flag("absent"));
    }

    #[test]
    fn typed_defaults_and_errors() {
        let a = Args::parse(toks("train --lr 0.01")).unwrap();
        assert_eq!(a.get("lr", 0.5f64).unwrap(), 0.01);
        assert_eq!(a.get("batch", 100usize).unwrap(), 100);
        let bad = Args::parse(toks("train --batch abc")).unwrap();
        assert!(bad.get("batch", 1usize).is_err());
    }

    #[test]
    fn negative_numbers_are_values_not_flags() {
        // "--shift -2" : -2 does not start with --, so it is a value
        let a = Args::parse(toks("x --shift -2")).unwrap();
        assert_eq!(a.get("shift", 0i32).unwrap(), -2);
    }

    #[test]
    fn rejects_extra_positionals_and_unknown() {
        assert!(Args::parse(toks("a b")).is_err());
        let a = Args::parse(toks("run --good 1 --bad 2")).unwrap();
        assert!(a.expect_known(&["good"]).is_err());
        assert!(a.expect_known(&["good", "bad"]).is_ok());
    }
}
