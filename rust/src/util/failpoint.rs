//! Deterministic fault injection for crash-safety tests.
//!
//! A *failpoint* is a named site in the code (`failpoint::check("sweep.run_job")?`)
//! that normally does nothing.  When armed — via the `ALLPAIRS_FAILPOINTS`
//! environment variable or the test API ([`arm`]) — it counts hits and
//! *fires* on a chosen hit, in one of three modes:
//!
//! * `error` — `check` returns an `Err`, exercising error-handling paths
//!   (the scheduler's retry logic, for example);
//! * `panic` — `check` panics, exercising panic isolation
//!   (`catch_unwind`, poisoned-lock recovery);
//! * `exit[:code]` — the process exits immediately (default code 86),
//!   simulating a hard crash / OOM kill for end-to-end resume tests.
//!
//! Spec grammar (env var holds `;`-separated specs):
//!
//! ```text
//! name=mode[:code][@after[xTimes]]
//! ```
//!
//! `after` (default 1) is the 1-based hit on which the point first
//! fires; it then fires for `times` (default 1) consecutive hits and
//! goes silent.  `sweep.run_job=error@1x2` fails the first two
//! attempts and lets the third through — exactly the shape a retry
//! test needs.  Countdowns are keyed on global hit order, so with a
//! single worker the firing site is fully deterministic; with several
//! workers the *count* of fires is still exact.
//!
//! When nothing has ever been armed, [`check`] is a single relaxed
//! atomic load — safe to leave in production paths.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};

/// Environment variable holding `;`-separated failpoint specs.
pub const ENV_VAR: &str = "ALLPAIRS_FAILPOINTS";

/// Default process exit code for `exit`-mode fires (distinctive, so CI
/// can assert the crash was the injected one).
pub const EXIT_CODE: i32 = 86;

/// What happens when an armed failpoint fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// `check` returns an error.
    Error,
    /// `check` panics (unwinds).
    Panic,
    /// The process exits with the given code.
    Exit(i32),
}

/// One armed failpoint: fires on hits `after ..= after + times - 1`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FailSpec {
    pub mode: Mode,
    /// 1-based hit on which the point first fires.
    pub after: u64,
    /// Number of consecutive hits that fire (then the point goes silent).
    pub times: u64,
}

#[derive(Debug)]
struct State {
    spec: FailSpec,
    hits: u64,
}

/// Fast path: false until the first arm (env or test API) ever happens.
static ANY_ARMED: AtomicBool = AtomicBool::new(false);

fn registry() -> &'static Mutex<HashMap<String, State>> {
    static REGISTRY: OnceLock<Mutex<HashMap<String, State>>> = OnceLock::new();
    REGISTRY.get_or_init(|| {
        let mut map = HashMap::new();
        if let Ok(env) = std::env::var(ENV_VAR) {
            match parse_specs(&env) {
                Ok(specs) => {
                    for (name, spec) in specs {
                        map.insert(name, State { spec, hits: 0 });
                    }
                }
                Err(e) => eprintln!("warning: ignoring bad {ENV_VAR}: {e}"),
            }
        }
        if !map.is_empty() {
            ANY_ARMED.store(true, Ordering::Release);
        }
        Mutex::new(map)
    })
}

fn lock_registry() -> MutexGuard<'static, HashMap<String, State>> {
    // A panic-mode fire unwinds while holding no lock, but a panicking
    // *test* thread may still poison this mutex via an assert between
    // arm/disarm calls; the map itself is always consistent.
    registry().lock().unwrap_or_else(|p| p.into_inner())
}

/// Parse a `;`-separated spec list (the `ALLPAIRS_FAILPOINTS` grammar).
pub fn parse_specs(text: &str) -> crate::Result<Vec<(String, FailSpec)>> {
    let mut out = Vec::new();
    for item in text.split(';') {
        let item = item.trim();
        if item.is_empty() {
            continue;
        }
        let (name, rhs) = item
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("failpoint spec {item:?}: expected name=mode"))?;
        out.push((name.trim().to_string(), parse_one(rhs.trim())?));
    }
    Ok(out)
}

fn parse_one(rhs: &str) -> crate::Result<FailSpec> {
    // rhs = mode[:code][@after[xTimes]] — times lives inside the `@`
    // suffix so mode names containing `x` (exit) stay unambiguous.
    let (mode_part, after, times) = match rhs.split_once('@') {
        None => (rhs, 1, 1),
        Some((m, suffix)) => {
            let (a, t) = match suffix.split_once('x') {
                None => (suffix, None),
                Some((a, t)) => (a, Some(t)),
            };
            let after = a
                .parse::<u64>()
                .map_err(|e| anyhow::anyhow!("failpoint countdown {a:?}: {e}"))?;
            let times = match t {
                None => 1,
                Some(t) => t
                    .parse::<u64>()
                    .map_err(|e| anyhow::anyhow!("failpoint times {t:?}: {e}"))?,
            };
            (m, after, times)
        }
    };
    anyhow::ensure!(after >= 1, "failpoint countdown must be >= 1 (1-based hit)");
    anyhow::ensure!(times >= 1, "failpoint times must be >= 1");
    let mode = match mode_part.split_once(':') {
        Some(("exit", code)) => Mode::Exit(
            code.parse::<i32>()
                .map_err(|e| anyhow::anyhow!("failpoint exit code {code:?}: {e}"))?,
        ),
        None => match mode_part {
            "error" => Mode::Error,
            "panic" => Mode::Panic,
            "exit" => Mode::Exit(EXIT_CODE),
            other => anyhow::bail!("unknown failpoint mode {other:?} (error | panic | exit[:code])"),
        },
        Some(_) => anyhow::bail!("unknown failpoint mode {mode_part:?} (error | panic | exit[:code])"),
    };
    Ok(FailSpec { mode, after, times })
}

/// Arm `name` programmatically (test API).  Resets its hit counter.
pub fn arm(name: &str, spec: FailSpec) {
    let mut reg = lock_registry();
    reg.insert(name.to_string(), State { spec, hits: 0 });
    ANY_ARMED.store(true, Ordering::Release);
}

/// Arm from a spec string, e.g. `arm_str("sweep.run_job", "error@1x2")`.
pub fn arm_str(name: &str, spec: &str) -> crate::Result<()> {
    arm(name, parse_one(spec)?);
    Ok(())
}

/// Disarm `name` (no-op if not armed).
pub fn disarm(name: &str) {
    lock_registry().remove(name);
}

/// Hits recorded for `name` so far (0 if never armed).
pub fn hits(name: &str) -> u64 {
    lock_registry().get(name).map(|s| s.hits).unwrap_or(0)
}

/// Evaluate the failpoint `name`: a no-op branch while disarmed, else
/// count a hit and fire per the armed [`FailSpec`].
pub fn check(name: &str) -> crate::Result<()> {
    if !ANY_ARMED.load(Ordering::Acquire) {
        return Ok(());
    }
    let fired = {
        let mut reg = lock_registry();
        match reg.get_mut(name) {
            None => return Ok(()),
            Some(state) => {
                state.hits += 1;
                let h = state.hits;
                let s = state.spec;
                (h >= s.after && h < s.after + s.times).then_some(s.mode)
            }
        }
    };
    match fired {
        None => Ok(()),
        Some(Mode::Error) => Err(anyhow::anyhow!("failpoint {name} fired (injected error)")),
        Some(Mode::Panic) => panic!("failpoint {name} fired (injected panic)"),
        Some(Mode::Exit(code)) => {
            eprintln!("failpoint {name} fired: exiting with code {code} (injected crash)");
            std::process::exit(code);
        }
    }
}

/// Global serialization lock for tests that arm shared failpoint names.
/// Failpoint state is process-global; concurrent tests arming the same
/// site would race on the hit counter.
pub fn serial_guard() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_is_a_noop() {
        let _g = serial_guard();
        assert!(check("fp.never_armed").is_ok());
        assert_eq!(hits("fp.never_armed"), 0);
    }

    #[test]
    fn countdown_fires_on_the_nth_hit_for_t_hits() {
        let _g = serial_guard();
        arm_str("fp.count", "error@3x2").unwrap();
        assert!(check("fp.count").is_ok()); // hit 1
        assert!(check("fp.count").is_ok()); // hit 2
        assert!(check("fp.count").is_err()); // hit 3: fires
        assert!(check("fp.count").is_err()); // hit 4: fires
        assert!(check("fp.count").is_ok()); // hit 5: exhausted
        assert_eq!(hits("fp.count"), 5);
        disarm("fp.count");
        assert!(check("fp.count").is_ok());
    }

    #[test]
    fn panic_mode_unwinds() {
        let _g = serial_guard();
        arm_str("fp.panics", "panic").unwrap();
        let caught = std::panic::catch_unwind(|| {
            let _ = check("fp.panics");
        });
        disarm("fp.panics");
        assert!(caught.is_err());
    }

    #[test]
    fn spec_grammar_round_trips() {
        let specs = parse_specs("a=error; b=panic@4 ;c=exit:7@2x3;d=exit").unwrap();
        assert_eq!(specs.len(), 4);
        assert_eq!(
            specs[0],
            ("a".into(), FailSpec { mode: Mode::Error, after: 1, times: 1 })
        );
        assert_eq!(
            specs[1],
            ("b".into(), FailSpec { mode: Mode::Panic, after: 4, times: 1 })
        );
        assert_eq!(
            specs[2],
            ("c".into(), FailSpec { mode: Mode::Exit(7), after: 2, times: 3 })
        );
        assert_eq!(
            specs[3],
            ("d".into(), FailSpec { mode: Mode::Exit(EXIT_CODE), after: 1, times: 1 })
        );
    }

    #[test]
    fn bad_specs_are_rejected() {
        assert!(parse_specs("nomode").is_err());
        assert!(parse_specs("a=explode").is_err());
        assert!(parse_specs("a=error@0").is_err());
        assert!(parse_specs("a=error@x").is_err());
        assert!(parse_specs("a=exit:abc").is_err());
        assert!(parse_specs("a=panic:3").is_err());
    }
}
