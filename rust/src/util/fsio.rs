//! Durable file writes: atomic replace via temp file + fsync + rename.
//!
//! The crash-safety argument (DESIGN.md §10): the bytes are first
//! written to a temporary file *in the target's directory* (same
//! filesystem, so the rename is atomic), fsynced so the data is on disk
//! before the name exists, then renamed over the target — POSIX
//! guarantees readers see either the old complete file or the new
//! complete file, never a torn mixture.  Finally the directory is
//! fsynced so the rename itself survives a power cut.

use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// Atomically replace `path` with `bytes`.  On return, either the old
/// content or the new content is fully on disk — never a torn write.
pub fn write_atomic(path: impl AsRef<Path>, bytes: &[u8]) -> crate::Result<()> {
    let path = path.as_ref();
    let parent = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => std::path::PathBuf::from("."),
    };
    std::fs::create_dir_all(&parent)?;
    let tmp = parent.join(tmp_name(path));
    // Scope the handle so it is closed before the rename (Windows
    // requires it; on Unix it is merely tidy).
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(e.into());
    }
    sync_dir(&parent);
    Ok(())
}

/// Unique-per-process-and-call temp name beside the target, so
/// concurrent writers (sweep workers, parallel tests) never collide and
/// a leftover temp from a crash is identifiable by its prefix.
fn tmp_name(path: &Path) -> String {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let stem = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "file".into());
    format!(
        ".{stem}.tmp.{}.{}",
        std::process::id(),
        COUNTER.fetch_add(1, Ordering::Relaxed)
    )
}

/// Fsync a directory so a completed rename is durable.  Best-effort:
/// not all platforms/filesystems support directory fsync, and a failure
/// here never loses data already renamed into place.
fn sync_dir(dir: &Path) {
    #[cfg(unix)]
    {
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
    #[cfg(not(unix))]
    let _ = dir;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_replaces() {
        let dir = std::env::temp_dir().join(format!("allpairs_fsio_{}", std::process::id()));
        let p = dir.join("nested/out.txt");
        write_atomic(&p, b"first").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"first");
        write_atomic(&p, b"second, longer content").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"second, longer content");
        // no temp litter left behind
        let leftovers: Vec<_> = std::fs::read_dir(p.parent().unwrap())
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "temp files left: {leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bare_filename_writes_to_cwd() {
        let name = format!("allpairs_fsio_bare_{}.txt", std::process::id());
        write_atomic(&name, b"x").unwrap();
        assert_eq!(std::fs::read(&name).unwrap(), b"x");
        let _ = std::fs::remove_file(&name);
    }
}
