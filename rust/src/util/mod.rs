//! In-tree substrates replacing unavailable third-party crates.
//!
//! This reproduction builds fully offline against a minimal vendored
//! dependency set (`xla`, `anyhow`); the conveniences a richer set would
//! provide are implemented here:
//!
//! * [`json`]  — a complete JSON parser/writer (serde_json stand-in),
//!   used for the artifact manifest, configs and result files.
//! * [`cli`]   — a small declarative argument parser (clap stand-in).
//! * [`bench`] — a measured micro-benchmark harness (criterion stand-in)
//!   used by `cargo bench` targets.
//! * [`fsio`] — durable file writes (atomic temp + fsync + rename).
//! * [`crc32`] — CRC-32 integrity footer for binary formats.
//! * [`failpoint`] — deterministic fault injection (a `fail`-crate
//!   stand-in) driving the crash-safety test suite.

pub mod bench;
pub mod cli;
pub mod crc32;
pub mod failpoint;
pub mod fsio;
pub mod json;
