//! In-tree substrates replacing unavailable third-party crates.
//!
//! This reproduction builds fully offline against a minimal vendored
//! dependency set (`xla`, `anyhow`); the conveniences a richer set would
//! provide are implemented here:
//!
//! * [`json`]  — a complete JSON parser/writer (serde_json stand-in),
//!   used for the artifact manifest, configs and result files.
//! * [`cli`]   — a small declarative argument parser (clap stand-in).
//! * [`bench`] — a measured micro-benchmark harness (criterion stand-in)
//!   used by `cargo bench` targets.

pub mod bench;
pub mod cli;
pub mod json;
