//! Minimal, API-compatible subset of the `anyhow` crate, vendored so the
//! workspace builds with no crates.io access (see DESIGN.md §5.4).
//!
//! Supported surface (everything this repo uses):
//!
//! * [`Error`] / [`Result`] — a string-message error with an optional
//!   source chain; like real `anyhow`, `Error` deliberately does **not**
//!   implement `std::error::Error` (that coherence choice is what allows
//!   the blanket `From<E: std::error::Error>` conversion `?` relies on).
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the formatting macros.
//! * `{:#}` alternate display prints the source chain, mirroring
//!   anyhow's "cause: ..." output used by the CLI's error reporting.

use std::fmt;

/// `Result` with a defaulted error type, as in the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-message error with an optional boxed source.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` entry point).
    pub fn msg(msg: impl fmt::Display) -> Self {
        Self {
            msg: msg.to_string(),
            source: None,
        }
    }

    /// Construct from a concrete error value, keeping it as the source.
    pub fn new<E: std::error::Error + Send + Sync + 'static>(err: E) -> Self {
        Self {
            msg: err.to_string(),
            source: Some(Box::new(err)),
        }
    }

    /// Walk the source chain (root cause last).
    pub fn chain(&self) -> impl Iterator<Item = &(dyn std::error::Error + 'static)> {
        let mut next = self
            .source
            .as_deref()
            .map(|e| e as &(dyn std::error::Error + 'static));
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            for cause in self.chain() {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        for cause in self.chain() {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        Error::new(err)
    }
}

/// Format an [`Error`] from format-string arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
        assert_eq!(e.chain().count(), 1);
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        fn bails() -> Result<()> {
            bail!("nope: {}", "reason");
        }
        assert_eq!(bails().unwrap_err().to_string(), "nope: reason");
        fn ensures(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert!(ensures(1).is_ok());
        assert!(ensures(-1).is_err());
    }

    #[test]
    fn alternate_display_prints_chain() {
        let e = Error::new(io_err());
        let s = format!("{e:#}");
        assert!(s.contains("disk on fire"));
    }
}
