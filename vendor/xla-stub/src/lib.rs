//! Stub of the `xla` PJRT crate's API surface used by `allpairs`.
//!
//! The real crate binds a C++ PJRT plugin, which cannot be built in this
//! offline environment.  This stub keeps the `pjrt` feature *compiling*
//! (so the PJRT runtime code stays type-checked and ready) while failing
//! cleanly at runtime: [`PjRtClient::cpu`] returns an error explaining
//! that no plugin is linked.  Host-side [`Literal`] construction works
//! for real, because tests exercise it.
//!
//! To run against actual hardware, point the `xla` dependency of
//! `rust/Cargo.toml` at the real crate instead of this path stub; the
//! API names and signatures here mirror the subset `allpairs` uses.

use std::borrow::Borrow;
use std::fmt;
use std::marker::PhantomData;
use std::path::Path;
use std::rc::Rc;

/// Stub error type (string message).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn no_plugin<T>() -> Result<T> {
    Err(Error(
        "no PJRT plugin linked: this build uses the in-tree xla API stub; \
         swap vendor/xla-stub for the real xla crate to execute artifacts"
            .to_string(),
    ))
}

/// Element types a [`Literal`] can hold (subset: f32, u32).
pub trait NativeType: Copy {
    fn store(values: Vec<Self>) -> Storage;
    fn load(storage: &Storage) -> Option<&[Self]>;
}

/// Backing storage of a literal.
#[derive(Debug, Clone, PartialEq)]
pub enum Storage {
    F32(Vec<f32>),
    U32(Vec<u32>),
}

impl NativeType for f32 {
    fn store(values: Vec<Self>) -> Storage {
        Storage::F32(values)
    }
    fn load(storage: &Storage) -> Option<&[Self]> {
        match storage {
            Storage::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for u32 {
    fn store(values: Vec<Self>) -> Storage {
        Storage::U32(values)
    }
    fn load(storage: &Storage) -> Option<&[Self]> {
        match storage {
            Storage::U32(v) => Some(v),
            _ => None,
        }
    }
}

/// Host-side array shape (dims only; dtype lives in the storage).
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Device shape: array or tuple (the runtime only matches on `Tuple`).
#[derive(Debug, Clone)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

/// A host-resident dense literal.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(value: T) -> Literal {
        Literal {
            storage: T::store(vec![value]),
            dims: Vec::new(),
        }
    }

    /// Rank-1 f32 literal.
    pub fn vec1(values: &[f32]) -> Literal {
        Literal {
            storage: Storage::F32(values.to_vec()),
            dims: vec![values.len() as i64],
        }
    }

    /// Reshape without copying semantics (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = match &self.storage {
            Storage::F32(v) => v.len(),
            Storage::U32(v) => v.len(),
        };
        if want as usize != have {
            return Err(Error(format!(
                "reshape {dims:?} needs {want} elements, literal has {have}"
            )));
        }
        Ok(Literal {
            storage: self.storage.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape {
            dims: self.dims.clone(),
        })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::load(&self.storage)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error("literal dtype mismatch".to_string()))
    }

    /// Stub literals are never tuples.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error("literal is not a tuple".to_string()))
    }
}

/// Parsed HLO module (stub: retains nothing).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        // Reading the file keeps manifest-vs-disk validation honest.
        std::fs::read_to_string(path.as_ref())
            .map(|_| HloModuleProto { _priv: () })
            .map_err(|e| Error(format!("reading {}: {e}", path.as_ref().display())))
    }
}

/// An XLA computation (stub).
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// PJRT client.  `Rc` marker keeps the stub `!Send`, matching the real
/// crate's threading contract that the sweep scheduler is built around.
pub struct PjRtClient {
    _not_send: PhantomData<Rc<()>>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        no_plugin()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        no_plugin()
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        no_plugin()
    }
}

/// Compiled executable (stub: cannot be constructed).
pub struct PjRtLoadedExecutable {
    _not_send: PhantomData<Rc<()>>,
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        no_plugin()
    }

    pub fn execute_b<L: Borrow<PjRtBuffer>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        no_plugin()
    }
}

/// Device buffer (stub: cannot be constructed).
pub struct PjRtBuffer {
    _not_send: PhantomData<Rc<()>>,
}

impl PjRtBuffer {
    pub fn on_device_shape(&self) -> Result<Shape> {
        no_plugin()
    }

    pub fn to_literal_sync(&self) -> Result<Literal> {
        no_plugin()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap().len(), 6);
        assert!(lit.reshape(&[7]).is_err());
        assert!(r.to_vec::<u32>().is_err());
    }

    #[test]
    fn client_reports_missing_plugin() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("no PJRT plugin"));
    }
}
