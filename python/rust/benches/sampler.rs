fn main() {}
