fn main() {}
