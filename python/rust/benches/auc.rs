fn main() {}
