fn main() {}
