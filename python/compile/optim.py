"""L2 optimizers as pure pytree transforms.

Two optimizers, matching the paper's experimental setup:

* :class:`SGDMomentum` — plain SGD with heavy-ball momentum, used with the
  pairwise hinge/square losses and the logistic baseline.
* :class:`PESG` — the Proximal Epoch Stochastic Gradient method of
  Guo et al. 2020, the optimizer LIBAUC pairs with the AUCM min-max loss:
  descent on (w, a, b), *ascent* on alpha, plus an L2 "proximal" pull of
  the weights toward a reference point (we use weight decay toward zero,
  the stateless variant, so artifacts stay stateless beyond momentum).

Both expose ``init(params) -> state`` and
``update(grads, state, params, lr) -> (new_params, new_state)`` and are
fully jittable, so a whole train step lowers into a single HLO module.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["SGDMomentum", "PESG"]


@dataclasses.dataclass(frozen=True)
class SGDMomentum:
    """Heavy-ball SGD: ``v <- mu v + g;  p <- p - lr v``."""

    momentum: float = 0.9

    def init(self, params):
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def update(self, grads, state, params, lr):
        new_state = jax.tree_util.tree_map(
            lambda v, g: self.momentum * v + g, state, grads
        )
        new_params = jax.tree_util.tree_map(
            lambda p, v: p - lr * v, params, new_state
        )
        return new_params, new_state


@dataclasses.dataclass(frozen=True)
class PESG:
    """PESG for the AUCM min-max objective (Guo et al. 2020).

    The caller packs the AUCM auxiliary variables into the params pytree
    under the key ``"aucm_aux"`` as ``[a, b, alpha]``.  PESG descends in
    everything except ``alpha``, which it *ascends* (gradient ascent on the
    dual variable), clipping ``alpha >= 0``.  ``gamma`` is the proximal
    weight-decay coefficient on the primal weights.
    """

    momentum: float = 0.9
    gamma: float = 2e-3
    aux_key: str = "aucm_aux"

    def init(self, params):
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def update(self, grads, state, params, lr):
        # Heavy-ball on everything (same buffer for aux; sign handled below).
        new_state = jax.tree_util.tree_map(
            lambda v, g: self.momentum * v + g, state, grads
        )

        def step(path_is_aux, p, v):
            if path_is_aux:
                # aux = [a, b, alpha]: descend a, b; ascend alpha; alpha >= 0.
                sign = jnp.array([1.0, 1.0, -1.0], p.dtype)
                out = p - lr * sign * v
                return out.at[2].set(jnp.maximum(out[2], 0.0))
            return p - lr * (v + self.gamma * p)

        flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
        flat_v = jax.tree_util.tree_leaves(new_state)
        new_leaves = []
        for (path, p), v in zip(flat_p, flat_v):
            is_aux = any(
                getattr(entry, "key", None) == self.aux_key for entry in path
            )
            new_leaves.append(step(is_aux, p, v))
        new_params = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(params), new_leaves
        )
        return new_params, new_state
