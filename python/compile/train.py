"""L2 train-step factories: model + loss + optimizer fused into one jit.

A *training state* is the pytree ``(params, opt_state)``.  For the AUCM
loss, ``params`` additionally carries the auxiliary variables under
``params["aucm_aux"] = [a, b, alpha]`` and the optimizer is PESG; for all
other losses the optimizer is SGD with momentum.  The whole step —
forward, loss (Pallas kernels for the pairwise losses), backward, update —
lowers into a single HLO module per (model, loss, batch-size) variant, so
the Rust runtime performs exactly one PJRT execution per training step.

Calling conventions (what the AOT artifacts expose, see ``aot.py``):

* ``init(seed: u32[]) -> state...``                       (flat tensors)
* ``train(state..., x, is_pos, is_neg, lr) -> (state..., loss, scores)``
* ``predict(state..., x) -> scores``
* ``loss_eval(scores, is_pos, is_neg) -> loss``           (the section-5
  monitoring use case: full-set loss in O(n log n))
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import losses as losses_mod
from . import model as model_mod
from . import optim as optim_mod

__all__ = [
    "make_optimizer",
    "make_init",
    "make_train_step",
    "make_predict",
    "make_loss_eval",
    "MARGIN",
]

# The paper keeps the margin at its default m = 1 for all experiments.
MARGIN = 1.0


def make_optimizer(loss_spec):
    """PESG for the AUCM min-max loss, SGD+momentum for everything else."""
    if loss_spec.needs_aux:
        return optim_mod.PESG()
    return optim_mod.SGDMomentum()


def _batch_loss(loss_spec, params, scores, is_pos, is_neg):
    if loss_spec.needs_aux:
        return losses_mod.aucm(scores, is_pos, is_neg, params["aucm_aux"], MARGIN)
    if loss_spec.pairwise:
        return loss_spec.fn(scores, is_pos, is_neg, MARGIN)
    return loss_spec.fn(scores, is_pos, is_neg)


def make_init(model, loss_spec):
    """``init(seed) -> (params, opt_state)`` pytree."""
    optimizer = make_optimizer(loss_spec)

    def init(seed):
        key = jax.random.PRNGKey(seed)
        params = model.init(key)
        if loss_spec.needs_aux:
            params["aucm_aux"] = losses_mod.aucm_init_aux()
        opt_state = optimizer.init(params)
        return params, opt_state

    return init


def make_train_step(model, loss_spec):
    """One fused SGD/PESG step over a masked batch.

    ``step(state, x, is_pos, is_neg, lr) -> (state', loss, scores)``.
    """
    optimizer = make_optimizer(loss_spec)

    def step(state, x, is_pos, is_neg, lr):
        params, opt_state = state

        def objective(p):
            scores = model.apply(p, x)
            return _batch_loss(loss_spec, p, scores, is_pos, is_neg), scores

        (loss, scores), grads = jax.value_and_grad(objective, has_aux=True)(params)
        new_params, new_opt = optimizer.update(grads, opt_state, params, lr)
        return (new_params, new_opt), loss, scores

    return step


def make_predict(model):
    """``predict(state, x) -> scores`` (ignores the optimizer half)."""

    def predict(state, x):
        params, _ = state
        return model.apply(params, x)

    return predict


def make_loss_and_param_grad(model, loss_spec):
    """Full-batch loss + gradient w.r.t. the *model parameters*.

    The building block for deterministic full-batch optimizers (the
    paper's §5 proposes LBFGS with full batches): no optimizer state, no
    update rule — just ``(params, x, is_pos, is_neg) -> (loss, grads)``.
    The Rust L-BFGS driver (rust/src/train/lbfgs.rs) consumes the
    ``grad_*`` artifacts lowered from this.
    """
    if loss_spec.needs_aux:
        raise ValueError("param-grad artifacts support params-only losses")

    def loss_and_grad(params, x, is_pos, is_neg):
        def objective(p):
            scores = model.apply(p, x)
            return _batch_loss(loss_spec, p, scores, is_pos, is_neg)

        return jax.value_and_grad(objective)(params)

    return loss_and_grad


def make_loss_eval(loss_spec):
    """Full-set loss monitor on raw scores (paper section 5).

    Not defined for AUCM (its value depends on aux variables, not only on
    the score distribution).
    """
    if loss_spec.needs_aux:
        raise ValueError("loss_eval is not defined for the AUCM loss")

    def loss_eval(scores, is_pos, is_neg):
        if loss_spec.pairwise:
            return loss_spec.fn(scores, is_pos, is_neg, MARGIN)
        return loss_spec.fn(scores, is_pos, is_neg)

    return loss_eval
