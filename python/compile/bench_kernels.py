"""Kernel ablation bench: Pallas block size × input size.

Interpret-mode wallclock is CPU-numpy time, NOT a TPU proxy (DESIGN.md
§7) — the point of this ablation is *structural*: it verifies the
block-grid decomposition scales linearly in grid steps and that the
carry adds O(1) per block, and it documents the VMEM footprint per
configuration for the real-TPU estimate.

Run: ``python -m compile.bench_kernels [--out ../results/bench_kernels.csv]``
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import allpairs_hinge


def vmem_bytes(block: int) -> int:
    """Working-set estimate per grid step: 3 in + 2 out f32 blocks + carry."""
    return (3 + 2) * block * 4 + 8 * 4


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../results/bench_kernels.csv")
    parser.add_argument("--sizes", default="4096,16384,65536")
    parser.add_argument("--blocks", default="128,512,1024,4096")
    parser.add_argument("--repeats", type=int, default=3)
    args = parser.parse_args(argv)

    sizes = [int(s) for s in args.sizes.split(",")]
    blocks = [int(b) for b in args.blocks.split(",")]
    rng = np.random.default_rng(0)
    rows = ["n,block,grid_steps,vmem_bytes,median_seconds"]
    for n in sizes:
        s = jnp.asarray(rng.normal(0, 1, n).astype(np.float32))
        y = jnp.asarray((rng.random(n) < 0.3).astype(np.float32))
        for block in blocks:
            if block > n:
                continue
            fn = jax.jit(
                lambda s_, p_, q_, block=block: allpairs_hinge.hinge_loss_and_grad(
                    s_, p_, q_, 1.0, block=block
                )[0]
            )
            fn(s, y, 1 - y).block_until_ready()  # compile
            times = []
            for _ in range(args.repeats):
                t0 = time.perf_counter()
                fn(s, y, 1 - y).block_until_ready()
                times.append(time.perf_counter() - t0)
            med = sorted(times)[len(times) // 2]
            grid = -(-n // block)
            rows.append(f"{n},{block},{grid},{vmem_bytes(block)},{med:.6f}")
            print(rows[-1], flush=True)
    out = args.out
    import pathlib

    pathlib.Path(out).parent.mkdir(parents=True, exist_ok=True)
    pathlib.Path(out).write_text("\n".join(rows) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
