"""L2 models: pure-JAX pytree networks with a sigmoid last activation.

The paper trains a PyTorch ResNet20 (He et al. 2015) with a sigmoid last
activation (following the LIBAUC recommendation).  Our reproduction-scale
stand-in is :class:`MiniResNet` — the same architecture family (3x3 conv
stem, three residual stages, global average pooling, dense head, sigmoid)
sized so that a full hyper-parameter sweep finishes on one CPU (~80k
parameters at the default widths).  :class:`MLP` is a small feature-vector
model used by the quickstart example and tests.

Design choices (documented substitutions):

* **Norm layers**: ResNet20 uses BatchNorm; batch statistics are training
  state that would have to round-trip through the AOT artifacts.  We use a
  stateless per-channel RMS normalization with learned scale instead —
  same conditioning role, no running stats, exactly reproducible from the
  parameter pytree alone.
* Parameters are plain nested dicts; ``jax.tree_util`` flattening order is
  deterministic (sorted dict keys), which is what the AOT manifest and the
  Rust runtime rely on.

Both models expose ``init(key) -> params`` and ``apply(params, x) ->
scores`` with ``scores in (0, 1)`` of shape ``(batch,)``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["MLP", "MiniResNet", "MODELS", "param_count"]


def param_count(params) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))


def _he_normal(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


def _rms_norm(x, scale):
    """Stateless per-channel RMS norm (axis = channels, last)."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * lax.rsqrt(ms + 1e-6) * scale


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLP:
    """Fully-connected net: ``in_dim -> hidden... -> 1``, sigmoid output."""

    in_dim: int = 64
    hidden: Tuple[int, ...] = (64, 32)

    @property
    def name(self) -> str:
        return "mlp"

    @property
    def input_shape(self) -> Tuple[int, ...]:
        return (self.in_dim,)

    def init(self, key):
        dims = (self.in_dim, *self.hidden, 1)
        params = {}
        for i, (d_in, d_out) in enumerate(zip(dims[:-1], dims[1:])):
            key, sub = jax.random.split(key)
            params[f"dense{i}"] = {
                "w": _he_normal(sub, (d_in, d_out), d_in),
                "b": jnp.zeros((d_out,), jnp.float32),
            }
        return params

    def apply(self, params, x):
        h = x
        n_layers = len(self.hidden) + 1
        for i in range(n_layers):
            layer = params[f"dense{i}"]
            h = h @ layer["w"] + layer["b"]
            if i < n_layers - 1:
                h = jax.nn.relu(h)
        return jax.nn.sigmoid(h[:, 0])


# ---------------------------------------------------------------------------
# MiniResNet
# ---------------------------------------------------------------------------


def _conv(x, w, stride=1):
    """3x3 (or 1x1) NHWC conv, SAME padding."""
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


@dataclasses.dataclass(frozen=True)
class MiniResNet:
    """Residual CNN for ``(H, W, 3)`` images, sigmoid head.

    stem conv -> [stage(width, blocks) for width in widths] -> GAP ->
    dense(1) -> sigmoid.  The first block of every stage after the first
    downsamples by 2 with a 1x1-conv shortcut projection.
    """

    image_hw: int = 16
    widths: Tuple[int, ...] = (8, 16, 32)
    blocks_per_stage: int = 2

    @property
    def name(self) -> str:
        return "resnet"

    @property
    def input_shape(self) -> Tuple[int, ...]:
        return (self.image_hw, self.image_hw, 3)

    def init(self, key):
        params = {}
        key, sub = jax.random.split(key)
        c0 = self.widths[0]
        params["stem"] = {
            "w": _he_normal(sub, (3, 3, 3, c0), 3 * 9),
            "scale": jnp.ones((c0,), jnp.float32),
        }
        c_in = c0
        for si, c_out in enumerate(self.widths):
            for bi in range(self.blocks_per_stage):
                name = f"stage{si}_block{bi}"
                key, k1, k2, k3 = jax.random.split(key, 4)
                block = {
                    "w1": _he_normal(k1, (3, 3, c_in, c_out), c_in * 9),
                    "s1": jnp.ones((c_out,), jnp.float32),
                    "w2": _he_normal(k2, (3, 3, c_out, c_out), c_out * 9),
                    "s2": jnp.ones((c_out,), jnp.float32),
                }
                if c_in != c_out:
                    block["proj"] = _he_normal(k3, (1, 1, c_in, c_out), c_in)
                params[name] = block
                c_in = c_out
        key, sub = jax.random.split(key)
        params["head"] = {
            "w": _he_normal(sub, (c_in, 1), c_in),
            "b": jnp.zeros((1,), jnp.float32),
        }
        return params

    def apply(self, params, x):
        h = _conv(x, params["stem"]["w"])
        h = jax.nn.relu(_rms_norm(h, params["stem"]["scale"]))
        c_in = self.widths[0]
        for si, c_out in enumerate(self.widths):
            for bi in range(self.blocks_per_stage):
                block = params[f"stage{si}_block{bi}"]
                # Downsample at the first block of stages > 0.
                stride = 2 if (bi == 0 and si > 0) else 1
                shortcut = h
                if "proj" in block:
                    shortcut = _conv(h, block["proj"], stride=stride)
                elif stride != 1:
                    shortcut = h[:, ::stride, ::stride, :]
                y = _conv(h, block["w1"], stride=stride)
                y = jax.nn.relu(_rms_norm(y, block["s1"]))
                y = _conv(y, block["w2"])
                y = _rms_norm(y, block["s2"])
                h = jax.nn.relu(y + shortcut)
                c_in = c_out
        pooled = jnp.mean(h, axis=(1, 2))  # global average pool
        logits = pooled @ params["head"]["w"] + params["head"]["b"]
        return jax.nn.sigmoid(logits[:, 0])


MODELS = {
    "mlp": MLP(),
    "resnet": MiniResNet(),
}
