"""Pure-jnp reference oracles for the all-pairs losses.

This module is the correctness anchor of the whole stack.  Everything here
is written for clarity, not speed:

* ``naive_*`` implement the paper's equation (2) literally as an
  O(n^2) double sum over the outer-difference matrix.  They are the ground
  truth the Pallas kernels (and the Rust implementations, transitively via
  the AOT artifacts) are validated against, and they are also the "Naive"
  baseline of the paper's Figure 2 timing study.
* ``functional_*`` implement Algorithms 1 and 2 of the paper with plain
  ``jnp`` sort + cumsum (no Pallas).  They are a second, independently
  derived oracle: pytest asserts ``naive == functional == pallas``.

All functions use the masked convention: instead of a label vector
``y in {-1, +1}`` they take two float mask vectors ``is_pos`` and
``is_neg`` (each 0.0 or 1.0, never both 1 for the same element).  An
element with both masks zero is padding and contributes nothing — this is
what makes fixed-shape AOT artifacts exact for ragged final batches.

Notation matches the paper: ``m`` is the margin, positives are indexed by
``j``, negatives by ``k``, and the pairwise loss is

    L = sum_{j in I+} sum_{k in I-} ell(yhat_j - yhat_k)

with ``ell(z) = (m - z)^2`` (square) or ``(m - z)_+^2`` (squared hinge).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "naive_square",
    "naive_squared_hinge",
    "naive_square_grad",
    "naive_squared_hinge_grad",
    "functional_square",
    "functional_square_grad",
    "functional_squared_hinge",
    "functional_squared_hinge_grad",
    "logistic_loss",
    "logistic_grad",
    "pair_count",
]


def pair_count(is_pos, is_neg):
    """Number of (positive, negative) pairs — the normalizer ``n+ * n-``."""
    return jnp.sum(is_pos) * jnp.sum(is_neg)


# ---------------------------------------------------------------------------
# Naive O(n^2): the paper's equation (2), literally.
# ---------------------------------------------------------------------------


def _pair_matrix(scores, margin):
    """``D[j, k] = m - yhat_j + yhat_k`` for every ordered pair (j, k)."""
    return margin - scores[:, None] + scores[None, :]


def naive_square(scores, is_pos, is_neg, margin=1.0):
    """All-pairs square loss, O(n^2) time and memory."""
    d = _pair_matrix(scores, margin)
    w = is_pos[:, None] * is_neg[None, :]
    return jnp.sum(w * d * d)


def naive_squared_hinge(scores, is_pos, is_neg, margin=1.0):
    """All-pairs squared hinge loss, O(n^2) time and memory."""
    d = jnp.maximum(_pair_matrix(scores, margin), 0.0)
    w = is_pos[:, None] * is_neg[None, :]
    return jnp.sum(w * d * d)


def naive_square_grad(scores, is_pos, is_neg, margin=1.0):
    """Gradient of :func:`naive_square` w.r.t. ``scores`` (closed form).

    d L / d yhat_j = sum_k -2 (m - yhat_j + yhat_k)   for positives j
    d L / d yhat_k = sum_j  2 (m - yhat_j + yhat_k)   for negatives k
    """
    d = _pair_matrix(scores, margin)
    w = is_pos[:, None] * is_neg[None, :]
    g_pos = -2.0 * jnp.sum(w * d, axis=1)  # row j: sum over k
    g_neg = 2.0 * jnp.sum(w * d, axis=0)  # col k: sum over j
    return g_pos + g_neg


def naive_squared_hinge_grad(scores, is_pos, is_neg, margin=1.0):
    """Gradient of :func:`naive_squared_hinge` w.r.t. ``scores``."""
    d = jnp.maximum(_pair_matrix(scores, margin), 0.0)
    w = is_pos[:, None] * is_neg[None, :]
    g_pos = -2.0 * jnp.sum(w * d, axis=1)
    g_neg = 2.0 * jnp.sum(w * d, axis=0)
    return g_pos + g_neg


# ---------------------------------------------------------------------------
# Functional O(n) square loss: the paper's Algorithm 1.
# ---------------------------------------------------------------------------


def functional_square(scores, is_pos, is_neg, margin=1.0):
    """Algorithm 1: three coefficients, then one evaluation per negative.

    a+ = n+, b+ = sum_j 2(m - yhat_j), c+ = sum_j (m - yhat_j)^2 and
    L = sum_k a+ yhat_k^2 + b+ yhat_k + c+.  Linear time, no sort.
    """
    z = margin - scores
    a = jnp.sum(is_pos)
    b = jnp.sum(is_pos * 2.0 * z)
    c = jnp.sum(is_pos * z * z)
    return jnp.sum(is_neg * (a * scores * scores + b * scores + c))


def functional_square_grad(scores, is_pos, is_neg, margin=1.0):
    """Closed-form gradient of the all-pairs square loss in O(n).

    For a negative k:  2 a+ yhat_k + b+.
    For a positive j:  -2 [ n- (m - yhat_j) + sum_k yhat_k ].
    """
    z = margin - scores
    a = jnp.sum(is_pos)
    b = jnp.sum(is_pos * 2.0 * z)
    n_neg = jnp.sum(is_neg)
    sum_neg = jnp.sum(is_neg * scores)
    g_neg = is_neg * (2.0 * a * scores + b)
    g_pos = is_pos * (-2.0) * (n_neg * z + sum_neg)
    return g_neg + g_pos


# ---------------------------------------------------------------------------
# Functional O(n log n) squared hinge loss: the paper's Algorithm 2,
# vectorized with sort + cumsum (this is exactly what the Pallas kernel
# computes block-wise with a carried (a, b, c, t) state).
# ---------------------------------------------------------------------------


def _sorted_views(scores, is_pos, is_neg, margin):
    """Sort by augmented value v_i = yhat_i + m * I[y_i = -1] (ascending).

    Ties between a positive j and a negative k at equal v contribute exactly
    zero loss and zero gradient ((m - yhat_j + yhat_k) = v_k - v_j = 0), so
    any tie-break order is correct.
    """
    v = scores + margin * is_neg
    order = jnp.argsort(v)
    return order, scores[order], is_pos[order], is_neg[order]


def functional_squared_hinge(scores, is_pos, is_neg, margin=1.0):
    """Algorithm 2: sort by augmented value, sweep, evaluate on negatives."""
    _, s, p, q = _sorted_views(scores, is_pos, is_neg, margin)
    z = margin - s
    a = jnp.cumsum(p)  # eq. (22): running count of positives
    b = jnp.cumsum(p * 2.0 * z)  # eq. (23)
    c = jnp.cumsum(p * z * z)  # eq. (24)
    return jnp.sum(q * (a * s * s + b * s + c))  # eq. (25)


def functional_squared_hinge_grad(scores, is_pos, is_neg, margin=1.0):
    """Closed-form gradient of the all-pairs squared hinge loss, O(n log n).

    Two sweeps over the sort order (see DESIGN.md section 3):

    * ascending (the loss sweep) yields, for each negative k,
      ``2 [ a_k (m + yhat_k) - t_k ]`` where ``a_k``/``t_k`` are the running
      count / running sum of positive predictions below ``v_k``;
    * descending yields, for each positive j,
      ``-2 [ N_j (m - yhat_j) + T_j ]`` where ``N_j``/``T_j`` are the count /
      sum of negative predictions with ``v_k > yhat_j``.
    """
    order, s, p, q = _sorted_views(scores, is_pos, is_neg, margin)
    # Ascending sweep: coefficients over positives.
    a = jnp.cumsum(p)
    t = jnp.cumsum(p * s)
    g_neg = q * 2.0 * (a * (margin + s) - t)
    # Descending sweep: suffix sums over negatives (inclusive suffix is
    # correct — self terms and equal-v terms contribute zero).
    n_suf = jnp.cumsum(q[::-1])[::-1]
    t_suf = jnp.cumsum((q * s)[::-1])[::-1]
    g_pos = p * (-2.0) * (n_suf * (margin - s) + t_suf)
    g_sorted = g_neg + g_pos
    return jnp.zeros_like(scores).at[order].set(g_sorted)


# ---------------------------------------------------------------------------
# Logistic (binary cross-entropy) baseline: linear time, sums over examples.
# ---------------------------------------------------------------------------


def logistic_loss(scores, is_pos, is_neg):
    """Per-example logistic loss on sigmoid outputs ``scores in (0, 1)``.

    This is the paper's "Logistic" baseline: standard unweighted BCE, which
    is how most binary classifiers are trained with no imbalance handling.
    Scores are probabilities (the model's last activation is a sigmoid), so
    we clamp for numerical safety.
    """
    s = jnp.clip(scores, 1e-7, 1.0 - 1e-7)
    return -jnp.sum(is_pos * jnp.log(s) + is_neg * jnp.log1p(-s))


def logistic_grad(scores, is_pos, is_neg):
    """Closed-form gradient of :func:`logistic_loss` w.r.t. ``scores``."""
    s = jnp.clip(scores, 1e-7, 1.0 - 1e-7)
    return -is_pos / s + is_neg / (1.0 - s)
