"""Pallas kernels for the all-pairs squared hinge loss (paper Algorithm 2).

The hot spot of the paper is the post-sort sweep: a single pass over the
predictions sorted by augmented value ``v_i = yhat_i + m * I[y_i = -1]``
that carries three coefficients ``(a, b, c)`` (paper eqs. 22-24) and
evaluates ``a x^2 + b x + c`` at every negative (eq. 25).  We additionally
carry ``t = sum of positive predictions`` so the same sweep emits the
closed-form gradient for negatives, and we run a mirrored descending sweep
for the positive gradients (see DESIGN.md section 3).

TPU mapping
-----------
* The sort itself stays in XLA (``jnp.argsort`` -> ``lax.sort``); sorting
  inside a Pallas kernel buys nothing on TPU.
* Each kernel is a 1-D *sequential* grid over blocks of ``block`` elements.
  The running coefficients live in a ``(8,)`` carry block that every grid
  step maps to the same output window — on TPU this is the canonical
  revisited-accumulator pattern (the block stays resident in VMEM across
  steps); scalar state is tiny so SMEM vs VMEM is immaterial.
* Within a block the recursion (22)-(25) is computed as a vectorized
  ``cumsum`` — the VPU-friendly formulation of the paper's element-wise
  for-loop — then the carry is bumped by the block totals.
* ``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
  custom calls, and interpret-mode lowers to plain HLO that the Rust
  runtime runs as-is.  The BlockSpec structure is unchanged for a real TPU
  build.

Everything here is loss *and* gradient in one fused pass per direction:
2 kernel launches + 1 sort per evaluation, O(n log n) total work.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "hinge_loss_and_grad",
    "hinge_loss",
    "DEFAULT_BLOCK",
]

# 1024 f32 elements = 4 KiB per operand block; with 3 inputs + 2 outputs the
# working set is ~20 KiB, far under the ~16 MiB TPU VMEM budget, leaving
# room for double buffering of the HBM->VMEM pipeline.
DEFAULT_BLOCK = 1024


def _fwd_kernel(s_ref, p_ref, q_ref, carry_ref, loss_ref, gneg_ref, *, margin):
    """Ascending sweep: loss + gradient w.r.t. negative examples.

    Carry layout (carry_ref, shape (8,), only 0..3 used):
      [0] a  — running count of positives           (paper eq. 22)
      [1] b  — running sum of 2 (m - yhat_j)        (paper eq. 23)
      [2] c  — running sum of (m - yhat_j)^2        (paper eq. 24)
      [3] t  — running sum of yhat_j (for the gradient)
    """
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    s = s_ref[...]
    p = p_ref[...]
    q = q_ref[...]
    z = margin - s
    # Inclusive within-block cumsums, shifted by the carried prefix.
    a = carry_ref[0] + jnp.cumsum(p)
    b = carry_ref[1] + jnp.cumsum(p * 2.0 * z)
    c = carry_ref[2] + jnp.cumsum(p * z * z)
    t = carry_ref[3] + jnp.cumsum(p * s)
    # Paper eq. (25): evaluate G_{a,b,c} at every negative in the block.
    loss_ref[0] += jnp.sum(q * (a * s * s + b * s + c))
    # Closed-form negative gradient: 2 [ a_k (m + yhat_k) - t_k ].
    gneg_ref[...] = q * 2.0 * (a * (margin + s) - t)
    carry_ref[0] = a[-1]
    carry_ref[1] = b[-1]
    carry_ref[2] = c[-1]
    carry_ref[3] = t[-1]


def _bwd_kernel(s_ref, p_ref, q_ref, carry_ref, gpos_ref, *, margin):
    """Descending sweep: gradient w.r.t. positive examples.

    Operates on the *reversed* sorted arrays, so an inclusive cumsum here is
    an inclusive suffix-sum in ascending order.  Carry layout (only 0..1
    used): [0] N — count of negatives seen, [1] T — sum of their yhat_k.
    """
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    s = s_ref[...]
    p = p_ref[...]
    q = q_ref[...]
    n_cnt = carry_ref[0] + jnp.cumsum(q)
    t_sum = carry_ref[1] + jnp.cumsum(q * s)
    # Closed-form positive gradient: -2 [ N_j (m - yhat_j) + T_j ].
    gpos_ref[...] = p * (-2.0) * (n_cnt * (margin - s) + t_sum)
    carry_ref[0] = n_cnt[-1]
    carry_ref[1] = t_sum[-1]


def _pad_to_block(arrs, block):
    """Right-pad 1-D arrays to a multiple of ``block`` with zeros.

    Zero padding is exact: padded elements have both masks zero, so they
    update no carry and emit no loss/gradient.
    """
    n = arrs[0].shape[0]
    rem = (-n) % block
    if rem == 0:
        return arrs, n
    return tuple(jnp.pad(a, (0, rem)) for a in arrs), n


def _fwd_call(s, p, q, margin, block):
    n = s.shape[0]
    grid = n // block
    return pl.pallas_call(
        functools.partial(_fwd_kernel, margin=margin),
        grid=(grid,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))] * 3,
        out_specs=[
            pl.BlockSpec((8,), lambda i: (0,)),  # carry (revisited)
            pl.BlockSpec((1,), lambda i: (0,)),  # loss accumulator
            pl.BlockSpec((block,), lambda i: (i,)),  # per-element grad
        ],
        out_shape=[
            jax.ShapeDtypeStruct((8,), s.dtype),
            jax.ShapeDtypeStruct((1,), s.dtype),
            jax.ShapeDtypeStruct((n,), s.dtype),
        ],
        interpret=True,
    )(s, p, q)


def _bwd_call(s, p, q, margin, block):
    n = s.shape[0]
    grid = n // block
    return pl.pallas_call(
        functools.partial(_bwd_kernel, margin=margin),
        grid=(grid,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))] * 3,
        out_specs=[
            pl.BlockSpec((8,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((8,), s.dtype),
            jax.ShapeDtypeStruct((n,), s.dtype),
        ],
        interpret=True,
    )(s, p, q)


def hinge_loss_and_grad(scores, is_pos, is_neg, margin=1.0, block=DEFAULT_BLOCK):
    """All-pairs squared hinge loss and its gradient, O(n log n).

    Args:
      scores: (n,) f32 predictions.
      is_pos / is_neg: (n,) f32 {0,1} masks; both-zero rows are padding.
      margin: the paper's margin hyper-parameter ``m >= 0`` (static).
      block: Pallas block length; clamped to the (padded) input size.

    Returns:
      (loss, grad) with ``grad.shape == scores.shape``.
    """
    n = scores.shape[0]
    block = min(block, max(8, n))
    # Sort by augmented value (paper eq. 20); ties are benign (zero terms).
    v = scores + margin * is_neg
    order = jnp.argsort(v)
    s = scores[order]
    p = is_pos[order]
    q = is_neg[order]
    (s_p, p_p, q_p), n0 = _pad_to_block((s, p, q), block)
    _, loss, gneg = _fwd_call(s_p, p_p, q_p, margin, block)
    # Descending sweep == ascending sweep over the reversed arrays.
    _, gpos_rev = _bwd_call(s_p[::-1], p_p[::-1], q_p[::-1], margin, block)
    g_sorted = gneg[:n0] + gpos_rev[::-1][:n0]
    grad = jnp.zeros_like(scores).at[order].set(g_sorted)
    return loss[0], grad


def hinge_loss(scores, is_pos, is_neg, margin=1.0, block=DEFAULT_BLOCK):
    """Loss-only entry point (single ascending sweep)."""
    loss, _ = hinge_loss_and_grad(scores, is_pos, is_neg, margin, block)
    return loss
