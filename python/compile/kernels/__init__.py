"""L1 Pallas kernels: the paper's compute hot-spot.

``allpairs_hinge`` — Algorithm 2 sweep (O(n log n) squared hinge loss +
gradient); ``allpairs_square`` — Algorithm 1 reductions (O(n) square loss +
gradient); ``ref`` — pure-jnp oracles (naive O(n^2) + vectorized
functional) that the kernels are tested against.
"""

from . import ref  # noqa: F401
from .allpairs_hinge import (  # noqa: F401
    DEFAULT_BLOCK,
    hinge_loss,
    hinge_loss_and_grad,
)
from .allpairs_square import square_loss, square_loss_and_grad  # noqa: F401
