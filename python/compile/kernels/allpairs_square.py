"""Pallas kernels for the all-pairs square loss (paper Algorithm 1).

The square loss needs no sort: three global coefficients over the positives
(paper eqs. 11-13) plus three mirrored sums over the negatives fully
determine both the loss and its gradient.  We compute the six sums with a
block-grid *reduction* kernel (revisited accumulator, same pattern as the
hinge sweep), then emit per-element gradients with a second, embarrassingly
parallel map kernel.  Total O(n) work, two kernel launches.

Reduction layout (``sums`` output, shape (8,), 6 used):
  [0] n+            count of positives
  [1] b+ = sum 2(m - yhat_j)        over positives   (eq. 12)
  [2] c+ = sum (m - yhat_j)^2       over positives   (eq. 13)
  [3] n-            count of negatives
  [4] S- = sum yhat_k               over negatives
  [5] Q- = sum yhat_k^2             over negatives
from which  L = n+ * Q- + b+ * S- + c+ * n-           (eq. 15/16)
  grad_k =  2 n+ yhat_k + b+                           (negatives)
  grad_j = -2 [ n- (m - yhat_j) + S- ]                 (positives)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .allpairs_hinge import DEFAULT_BLOCK, _pad_to_block

__all__ = ["square_loss_and_grad", "square_loss"]


def _reduce_kernel(s_ref, p_ref, q_ref, sums_ref, *, margin):
    """Accumulate the six global sums across the sequential grid."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)

    s = s_ref[...]
    p = p_ref[...]
    q = q_ref[...]
    z = margin - s
    sums_ref[0] += jnp.sum(p)
    sums_ref[1] += jnp.sum(p * 2.0 * z)
    sums_ref[2] += jnp.sum(p * z * z)
    sums_ref[3] += jnp.sum(q)
    sums_ref[4] += jnp.sum(q * s)
    sums_ref[5] += jnp.sum(q * s * s)


def _grad_kernel(s_ref, p_ref, q_ref, sums_ref, g_ref, *, margin):
    """Elementwise map: closed-form gradient given the global sums."""
    s = s_ref[...]
    p = p_ref[...]
    q = q_ref[...]
    n_pos = sums_ref[0]
    b = sums_ref[1]
    n_neg = sums_ref[3]
    s_neg = sums_ref[4]
    g_neg = q * (2.0 * n_pos * s + b)
    g_pos = p * (-2.0) * (n_neg * (margin - s) + s_neg)
    g_ref[...] = g_neg + g_pos


def square_loss_and_grad(scores, is_pos, is_neg, margin=1.0, block=DEFAULT_BLOCK):
    """All-pairs square loss and gradient in O(n) (no sort).

    Same masked-input convention as the hinge kernel; see module docstring
    for the coefficient algebra.
    """
    n = scores.shape[0]
    block = min(block, max(8, n))
    (s, p, q), n0 = _pad_to_block((scores, is_pos, is_neg), block)
    np_ = s.shape[0]
    grid = np_ // block
    sums = pl.pallas_call(
        functools.partial(_reduce_kernel, margin=margin),
        grid=(grid,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))] * 3,
        out_specs=pl.BlockSpec((8,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((8,), s.dtype),
        interpret=True,
    )(s, p, q)
    loss = sums[0] * sums[5] + sums[1] * sums[4] + sums[2] * sums[3]
    grad_padded = pl.pallas_call(
        functools.partial(_grad_kernel, margin=margin),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((8,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), s.dtype),
        interpret=True,
    )(s, p, q, sums)
    return loss, grad_padded[:n0]


def square_loss(scores, is_pos, is_neg, margin=1.0, block=DEFAULT_BLOCK):
    """Loss-only entry point (reduction kernel only)."""
    loss, _ = square_loss_and_grad(scores, is_pos, is_neg, margin, block)
    return loss
