"""AOT compiler: lower every (model, loss, batch) variant to HLO text.

This is the only place Python touches the artifacts the Rust runtime
loads.  Interchange format is **HLO text**, not a serialized
``HloModuleProto``: jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 (what the published ``xla`` 0.1.6 crate links)
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Outputs, under ``artifacts/``:

* ``<name>.hlo.txt``   — one per artifact (see naming below),
* ``manifest.json``    — machine-readable registry the Rust
  ``runtime::artifact`` module consumes: per-artifact input signature
  (shape + dtype per tensor), output arity, state arity, and the batch
  size / loss / model / kind tags.

Artifact naming:
  ``init_<model>_<loss>``
  ``train_<model>_<loss>_bs<B>``
  ``predict_<model>_<loss>_bs<B>``
  ``loss_eval_<loss>_n<N>``

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile
drives this; it is a no-op at the Make level when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import losses as losses_mod
from . import model as model_mod
from . import train as train_mod

# The paper's batch-size grid (section 4.2); 5000 exists in the paper's grid
# but was never selected (Table 2) — we cap at 1000 to keep artifact count
# and sweep time reproduction-scale.  Documented in DESIGN.md section 2.
TRAIN_BATCH_SIZES = (10, 50, 100, 500, 1000)
PREDICT_BATCH = 1000
LOSS_EVAL_N = 4096
SWEEP_MODEL = "resnet"
SWEEP_LOSSES = ("hinge", "square", "logistic", "aucm")
# Quickstart/MLP variant: one loss, one batch size.
MLP_BATCH = 100
MLP_PREDICT_BATCH = 256
# Full-batch size for the deterministic L-BFGS artifacts (paper §5).
LBFGS_BATCH = 1024


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(avals):
    return [{"shape": list(a.shape), "dtype": str(a.dtype)} for a in avals]


class Builder:
    """Accumulates lowered artifacts + manifest entries."""

    def __init__(self, out_dir: pathlib.Path):
        self.out_dir = out_dir
        self.entries = []

    def add(
        self,
        name,
        fn,
        example_args,
        *,
        kind,
        model,
        loss,
        batch,
        n_state,
        n_outputs,
        state_indices=None,
    ):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = self.out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        flat, _ = jax.tree_util.tree_flatten(example_args)
        entry = {
            "name": name,
            "file": path.name,
            "kind": kind,
            "model": model,
            "loss": loss,
            "batch": batch,
            "n_state": n_state,
            "inputs": _sig(flat),
            "n_outputs": n_outputs,
        }
        if state_indices is not None:
            # which full-state slots this artifact consumes (predict only)
            entry["state_indices"] = state_indices
        self.entries.append(entry)
        print(
            f"  {name:34s} {len(text)/1024:9.1f} KiB  {time.time()-t0:5.1f}s",
            flush=True,
        )

    def write_manifest(self):
        manifest = {
            "format_version": 1,
            "margin": train_mod.MARGIN,
            "artifacts": self.entries,
        }
        (self.out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))


def _flat_state_fns(model, loss_spec):
    """Wrap pytree-level init/train/predict as flat-tensor functions.

    The flat order is ``jax.tree_util.tree_flatten`` order of the state
    pytree ``(params, opt_state)`` — deterministic (sorted dict keys), and
    recorded implicitly by the manifest input signatures.
    """
    init = train_mod.make_init(model, loss_spec)
    step = train_mod.make_train_step(model, loss_spec)
    predict = train_mod.make_predict(model)

    # Build the state treedef once from an abstract init evaluation.
    state0 = jax.eval_shape(init, jnp.uint32(0))
    flat0, treedef = jax.tree_util.tree_flatten(state0)
    n_state = len(flat0)

    def init_flat(seed):
        state = init(seed)
        return tuple(jax.tree_util.tree_leaves(state))

    def train_flat(*args):
        state_flat, rest = args[:n_state], args[n_state:]
        x, is_pos, is_neg, lr = rest
        state = jax.tree_util.tree_unflatten(treedef, list(state_flat))
        new_state, loss, scores = step(state, x, is_pos, is_neg, lr)
        return (*jax.tree_util.tree_leaves(new_state), loss, scores)

    # predict uses only the model parameters: XLA prunes unused entry
    # parameters at compile time, so lowering predict over the *full*
    # state would produce an executable whose input arity silently
    # disagrees with the manifest.  Instead we lower it over exactly the
    # leaves `model.apply` reads (model params, excluding AUCM's aux) and
    # record their positions within the full flat state in the manifest
    # (`state_indices`) so the Rust runtime can select them.
    params0, _opt0 = state0
    params_flat, params_treedef = jax.tree_util.tree_flatten(params0)
    paths = jax.tree_util.tree_flatten_with_path(params0)[0]
    aux_positions = {
        i
        for i, (path, _) in enumerate(paths)
        if any(getattr(e, "key", None) == "aucm_aux" for e in path)
    }
    # params occupy the first len(params_flat) slots of the flat state
    predict_indices = [i for i in range(len(params_flat)) if i not in aux_positions]

    def predict_flat(*args):
        sel, (x,) = args[: len(predict_indices)], args[len(predict_indices) :]
        sel_iter = iter(sel)
        leaves = [
            jnp.zeros(params_flat[i].shape, params_flat[i].dtype)
            if i in aux_positions
            else next(sel_iter)
            for i in range(len(params_flat))
        ]
        params = jax.tree_util.tree_unflatten(params_treedef, leaves)
        return (model.apply(params, x),)

    state_avals = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in flat0]
    predict_avals = [state_avals[i] for i in predict_indices]
    return (
        init_flat,
        train_flat,
        predict_flat,
        state_avals,
        n_state,
        predict_avals,
        predict_indices,
    )


def build_model_loss(b: Builder, model, loss_name, batch_sizes, predict_batch):
    loss_spec = losses_mod.LOSSES[loss_name]
    (
        init_flat,
        train_flat,
        predict_flat,
        state_avals,
        n_state,
        predict_avals,
        predict_indices,
    ) = _flat_state_fns(model, loss_spec)
    f32 = jnp.float32
    seed = jax.ShapeDtypeStruct((), jnp.uint32)
    b.add(
        f"init_{model.name}_{loss_name}",
        init_flat,
        (seed,),
        kind="init",
        model=model.name,
        loss=loss_name,
        batch=0,
        n_state=n_state,
        n_outputs=n_state,
    )
    for bs in batch_sizes:
        x = jax.ShapeDtypeStruct((bs, *model.input_shape), f32)
        mask = jax.ShapeDtypeStruct((bs,), f32)
        lr = jax.ShapeDtypeStruct((), f32)
        b.add(
            f"train_{model.name}_{loss_name}_bs{bs}",
            train_flat,
            (*state_avals, x, mask, mask, lr),
            kind="train",
            model=model.name,
            loss=loss_name,
            batch=bs,
            n_state=n_state,
            n_outputs=n_state + 2,
        )
    xp = jax.ShapeDtypeStruct((predict_batch, *model.input_shape), f32)
    b.add(
        f"predict_{model.name}_{loss_name}_bs{predict_batch}",
        predict_flat,
        (*predict_avals, xp),
        kind="predict",
        model=model.name,
        loss=loss_name,
        batch=predict_batch,
        n_state=len(predict_indices),
        n_outputs=1,
        state_indices=predict_indices,
    )


def build_param_grad(b: Builder, model, loss_name, n):
    """Full-batch ``grad_<model>_<loss>_n<N>`` artifact for L-BFGS.

    Inputs: (params..., x[N,...], is_pos[N], is_neg[N]);
    outputs: (loss, grads...) with grads in the params' flat order.
    """
    loss_spec = losses_mod.LOSSES[loss_name]
    fn = train_mod.make_loss_and_param_grad(model, loss_spec)
    params0 = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    flat0, treedef = jax.tree_util.tree_flatten(params0)
    n_params = len(flat0)

    def grad_flat(*args):
        params_flat, (x, is_pos, is_neg) = args[:n_params], args[n_params:]
        params = jax.tree_util.tree_unflatten(treedef, list(params_flat))
        loss, grads = fn(params, x, is_pos, is_neg)
        return (loss, *jax.tree_util.tree_leaves(grads))

    f32 = jnp.float32
    param_avals = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in flat0]
    x = jax.ShapeDtypeStruct((n, *model.input_shape), f32)
    mask = jax.ShapeDtypeStruct((n,), f32)
    b.add(
        f"grad_{model.name}_{loss_name}_n{n}",
        grad_flat,
        (*param_avals, x, mask, mask),
        kind="grad",
        model=model.name,
        loss=loss_name,
        batch=n,
        n_state=n_params,
        n_outputs=1 + n_params,
    )


def build_loss_eval(b: Builder, loss_name, n):
    loss_spec = losses_mod.LOSSES[loss_name]
    fn = train_mod.make_loss_eval(loss_spec)
    f32 = jnp.float32
    vec = jax.ShapeDtypeStruct((n,), f32)
    b.add(
        f"loss_eval_{loss_name}_n{n}",
        lambda s, p, q: (fn(s, p, q),),
        (vec, vec, vec),
        kind="loss_eval",
        model="",
        loss=loss_name,
        batch=n,
        n_state=0,
        n_outputs=1,
    )


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--quick",
        action="store_true",
        help="only the MLP quickstart artifacts (fast smoke build)",
    )
    args = parser.parse_args(argv)
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    b = Builder(out_dir)
    t0 = time.time()
    print("== MLP quickstart artifacts", flush=True)
    mlp = model_mod.MODELS["mlp"]
    build_model_loss(b, mlp, "hinge", (MLP_BATCH,), MLP_PREDICT_BATCH)
    # full-batch gradient artifacts for the L-BFGS extension (paper §5)
    for loss_name in ("hinge", "logistic"):
        build_param_grad(b, mlp, loss_name, LBFGS_BATCH)
    if not args.quick:
        print("== ResNet sweep artifacts", flush=True)
        resnet = model_mod.MODELS["resnet"]
        for loss_name in SWEEP_LOSSES:
            build_model_loss(b, resnet, loss_name, TRAIN_BATCH_SIZES, PREDICT_BATCH)
        print("== loss_eval monitors", flush=True)
        for loss_name in ("hinge", "square", "logistic"):
            build_loss_eval(b, loss_name, LOSS_EVAL_N)
    b.write_manifest()
    print(
        f"wrote {len(b.entries)} artifacts + manifest to {out_dir} "
        f"in {time.time()-t0:.1f}s"
    )


if __name__ == "__main__":
    sys.exit(main())
