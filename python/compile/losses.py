"""L2 training losses: differentiable wrappers over the L1 kernels.

Each pairwise loss is exposed as a ``jax.custom_vjp`` whose forward pass
runs the fused Pallas loss+gradient kernel and whose backward pass reuses
the gradient computed in the forward sweep (the closed form derived in
DESIGN.md section 3).  This keeps the O(n^2) pairwise matrix out of every
training artifact — a structural property asserted by
``python/tests/test_aot.py``.

Loss registry
-------------
``LOSSES`` maps the names used throughout the repo (and by the Rust
coordinator's manifest) to ``LossSpec`` entries:

* ``hinge``    — all-pairs squared hinge (the paper's contribution),
* ``square``   — all-pairs square loss (Algorithm 1),
* ``logistic`` — per-example BCE (the paper's "Logistic" baseline),
* ``aucm``     — LIBAUC's AUCM min-max loss (Yuan et al. 2020 baseline).

All take ``(scores, is_pos, is_neg)`` with {0,1} float masks (padding =
both zero) and return a scalar normalized by the number of pairs (or
examples), so learning rates are comparable across batch sizes.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import allpairs_hinge, allpairs_square, ref

__all__ = [
    "allpairs_squared_hinge",
    "allpairs_square_loss",
    "logistic",
    "aucm",
    "aucm_init_aux",
    "naive_squared_hinge",
    "naive_square",
    "LossSpec",
    "LOSSES",
]

_EPS = 1.0  # pair_count floor: avoids 0/0 on single-class batches


def _norm_pairs(is_pos, is_neg):
    return jnp.maximum(ref.pair_count(is_pos, is_neg), _EPS)


# ---------------------------------------------------------------------------
# Pallas-backed pairwise losses with custom VJP.
# ---------------------------------------------------------------------------


# ``margin`` is a nondiff static argument: it must stay a concrete Python
# float all the way into the Pallas kernel closure (a traced margin would be
# a captured constant, which pallas_call rejects).
@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _hinge_raw(scores, is_pos, is_neg, margin):
    loss, _ = allpairs_hinge.hinge_loss_and_grad(scores, is_pos, is_neg, margin)
    return loss


def _hinge_fwd(scores, is_pos, is_neg, margin):
    loss, grad = allpairs_hinge.hinge_loss_and_grad(scores, is_pos, is_neg, margin)
    return loss, grad


def _hinge_bwd(margin, grad, g):
    return (g * grad, None, None)


_hinge_raw.defvjp(_hinge_fwd, _hinge_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _square_raw(scores, is_pos, is_neg, margin):
    loss, _ = allpairs_square.square_loss_and_grad(scores, is_pos, is_neg, margin)
    return loss


def _square_fwd(scores, is_pos, is_neg, margin):
    loss, grad = allpairs_square.square_loss_and_grad(scores, is_pos, is_neg, margin)
    return loss, grad


def _square_bwd(margin, grad, g):
    return (g * grad, None, None)


_square_raw.defvjp(_square_fwd, _square_bwd)


def allpairs_squared_hinge(scores, is_pos, is_neg, margin=1.0):
    """Normalized all-pairs squared hinge loss (Pallas, O(n log n))."""
    return _hinge_raw(scores, is_pos, is_neg, margin) / _norm_pairs(is_pos, is_neg)


def allpairs_square_loss(scores, is_pos, is_neg, margin=1.0):
    """Normalized all-pairs square loss (Pallas, O(n))."""
    return _square_raw(scores, is_pos, is_neg, margin) / _norm_pairs(is_pos, is_neg)


# ---------------------------------------------------------------------------
# Naive O(n^2) variants — for Figure 2 baselines only, never in artifacts.
# ---------------------------------------------------------------------------


def naive_squared_hinge(scores, is_pos, is_neg, margin=1.0):
    """O(n^2) squared hinge via the pairwise matrix (autodiff gradient)."""
    return ref.naive_squared_hinge(scores, is_pos, is_neg, margin) / _norm_pairs(
        is_pos, is_neg
    )


def naive_square(scores, is_pos, is_neg, margin=1.0):
    """O(n^2) square loss via the pairwise matrix (autodiff gradient)."""
    return ref.naive_square(scores, is_pos, is_neg, margin) / _norm_pairs(
        is_pos, is_neg
    )


# ---------------------------------------------------------------------------
# Logistic baseline (linear time, sums over examples).
# ---------------------------------------------------------------------------


def logistic(scores, is_pos, is_neg):
    """Mean per-example BCE over non-padding elements."""
    n = jnp.maximum(jnp.sum(is_pos) + jnp.sum(is_neg), _EPS)
    return ref.logistic_loss(scores, is_pos, is_neg) / n


# ---------------------------------------------------------------------------
# AUCM min-max loss (LIBAUC baseline, Yuan et al. 2020).
# ---------------------------------------------------------------------------


def aucm_init_aux():
    """Initial auxiliary variables (a, b, alpha) for the AUCM loss."""
    return jnp.zeros((3,), jnp.float32)


def aucm(scores, is_pos, is_neg, aux, margin=1.0):
    """AUCM loss of Yuan et al. 2020 (masked, mean-normalized).

    L(w, a, b, alpha) = E+[(h - a)^2] + E-[(h - b)^2]
                        + 2 alpha (m + E-[h] - E+[h]) - alpha^2

    ``aux = [a, b, alpha]``.  The saddle point is found by descending in
    (w, a, b) and *ascending* in alpha — the PESG optimizer in ``optim.py``
    flips the sign of the alpha gradient, so this function just returns the
    scalar objective.
    """
    a, b, alpha = aux[0], aux[1], aux[2]
    n_pos = jnp.maximum(jnp.sum(is_pos), _EPS)
    n_neg = jnp.maximum(jnp.sum(is_neg), _EPS)
    mean_pos = jnp.sum(is_pos * scores) / n_pos
    mean_neg = jnp.sum(is_neg * scores) / n_neg
    var_pos = jnp.sum(is_pos * (scores - a) ** 2) / n_pos
    var_neg = jnp.sum(is_neg * (scores - b) ** 2) / n_neg
    return var_pos + var_neg + 2.0 * alpha * (margin + mean_neg - mean_pos) - alpha**2


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LossSpec:
    """A named training loss.

    Attributes:
      name: registry key (also used in artifact file names / manifest).
      fn: ``fn(scores, is_pos, is_neg) -> scalar`` (margin bound at m=1;
        AUCM additionally closes over the aux variables via ``train.py``).
      pairwise: True if the loss sums over (pos, neg) pairs.
      needs_aux: True if the optimizer state carries (a, b, alpha) + PESG.
    """

    name: str
    fn: Callable
    pairwise: bool
    needs_aux: bool = False


LOSSES = {
    "hinge": LossSpec("hinge", allpairs_squared_hinge, pairwise=True),
    "square": LossSpec("square", allpairs_square_loss, pairwise=True),
    "logistic": LossSpec("logistic", logistic, pairwise=False),
    "aucm": LossSpec("aucm", aucm, pairwise=True, needs_aux=True),
}
