fn main() {}
