fn main() {}
