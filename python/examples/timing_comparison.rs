fn main() {}
