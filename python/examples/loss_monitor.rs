fn main() {}
