"""Full-batch param-grad factory (the L-BFGS extension's L2 half)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import losses, model as mm, train

jax.config.update("jax_platform_name", "cpu")


def _batch(n=32, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 1, (n, 64)).astype(np.float32))
    y = jnp.asarray((rng.random(n) < 0.3).astype(np.float32))
    return x, y, 1.0 - y


def test_loss_and_grad_matches_autodiff():
    mlp = mm.MODELS["mlp"]
    spec = losses.LOSSES["hinge"]
    fn = train.make_loss_and_param_grad(mlp, spec)
    params = mlp.init(jax.random.PRNGKey(0))
    x, p, q = _batch()
    loss, grads = fn(params, x, p, q)
    # reference: direct value_and_grad of the composed objective
    ref_loss, ref_grads = jax.value_and_grad(
        lambda pr: losses.allpairs_squared_hinge(mlp.apply(pr, x), p, q)
    )(params)
    np.testing.assert_allclose(loss, ref_loss, rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(grads), jax.tree_util.tree_leaves(ref_grads)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-7)


def test_grad_descent_step_reduces_loss():
    mlp = mm.MODELS["mlp"]
    spec = losses.LOSSES["hinge"]
    fn = jax.jit(train.make_loss_and_param_grad(mlp, spec))
    params = mlp.init(jax.random.PRNGKey(1))
    x, p, q = _batch(64, 1)
    l0, g = fn(params, x, p, q)
    params2 = jax.tree_util.tree_map(lambda w, gw: w - 0.1 * gw, params, g)
    l1, _ = fn(params2, x, p, q)
    assert float(l1) < float(l0)


def test_rejects_aucm():
    mlp = mm.MODELS["mlp"]
    with pytest.raises(ValueError):
        train.make_loss_and_param_grad(mlp, losses.LOSSES["aucm"])


def test_grad_is_zero_on_single_class_batch():
    mlp = mm.MODELS["mlp"]
    spec = losses.LOSSES["hinge"]
    fn = train.make_loss_and_param_grad(mlp, spec)
    params = mlp.init(jax.random.PRNGKey(2))
    x, _, _ = _batch(16, 2)
    ones, zeros = jnp.ones(16), jnp.zeros(16)
    loss, grads = fn(params, x, ones, zeros)
    assert float(loss) == 0.0
    for leaf in jax.tree_util.tree_leaves(grads):
        np.testing.assert_allclose(leaf, 0.0)
