"""Model shape/finiteness/determinism checks for MLP and MiniResNet."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as mm

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("name", ["mlp", "resnet"])
class TestModels:
    def test_output_shape_and_range(self, name):
        m = mm.MODELS[name]
        params = m.init(jax.random.PRNGKey(0))
        for bs in (1, 4, 17):
            x = jax.random.normal(jax.random.PRNGKey(bs), (bs, *m.input_shape))
            s = m.apply(params, x)
            assert s.shape == (bs,)
            assert jnp.all((s > 0.0) & (s < 1.0)), "sigmoid output range"
            assert jnp.all(jnp.isfinite(s))

    def test_init_deterministic(self, name):
        m = mm.MODELS[name]
        p1 = m.init(jax.random.PRNGKey(42))
        p2 = m.init(jax.random.PRNGKey(42))
        for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
            np.testing.assert_array_equal(a, b)

    def test_different_seeds_differ(self, name):
        m = mm.MODELS[name]
        p1 = jax.tree_util.tree_leaves(m.init(jax.random.PRNGKey(0)))
        p2 = jax.tree_util.tree_leaves(m.init(jax.random.PRNGKey(1)))
        assert any(not np.allclose(a, b) for a, b in zip(p1, p2))

    def test_gradients_flow_to_all_params(self, name):
        m = mm.MODELS[name]
        params = m.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, *m.input_shape))

        def loss(p):
            return jnp.sum(m.apply(p, x) ** 2)

        grads = jax.grad(loss)(params)
        for leaf in jax.tree_util.tree_leaves(grads):
            assert jnp.all(jnp.isfinite(leaf))
        # at least one nonzero grad leaf per layer group
        nonzero = [bool(jnp.any(leaf != 0)) for leaf in jax.tree_util.tree_leaves(grads)]
        assert sum(nonzero) >= len(nonzero) // 2

    def test_flatten_order_stable(self, name):
        """tree_flatten order is what the AOT manifest relies on."""
        m = mm.MODELS[name]
        params = m.init(jax.random.PRNGKey(0))
        flat1, td1 = jax.tree_util.tree_flatten(params)
        flat2, td2 = jax.tree_util.tree_flatten(m.init(jax.random.PRNGKey(0)))
        assert td1 == td2
        assert [a.shape for a in flat1] == [a.shape for a in flat2]


def test_resnet_param_count_reproduction_scale():
    """~80k budget: big enough to learn, small enough to sweep on CPU."""
    m = mm.MODELS["resnet"]
    n = mm.param_count(m.init(jax.random.PRNGKey(0)))
    assert 20_000 < n < 200_000, n


def test_resnet_downsamples():
    """Spatial dims shrink by 2 at each later stage (GAP still works)."""
    m = mm.MiniResNet(image_hw=16)
    params = m.init(jax.random.PRNGKey(0))
    x = jnp.ones((2, 16, 16, 3))
    s = m.apply(params, x)
    assert s.shape == (2,)


def test_mlp_depth_configurable():
    m = mm.MLP(in_dim=10, hidden=(5,))
    params = m.init(jax.random.PRNGKey(0))
    assert set(params) == {"dense0", "dense1"}
    s = m.apply(params, jnp.ones((3, 10)))
    assert s.shape == (3,)
