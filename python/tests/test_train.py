"""Train-step factories and optimizers: learning actually happens."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import losses, model as mm, optim, train

jax.config.update("jax_platform_name", "cpu")


def _separable_batch(n=64, pos_frac=0.5, seed=0):
    """Linearly separable features: positives shifted by +2 along dim 0."""
    rng = np.random.default_rng(seed)
    y = (rng.random(n) < pos_frac).astype(np.float32)
    x = rng.normal(0, 1, (n, 64)).astype(np.float32)
    x[:, 0] += 2.0 * y
    return jnp.asarray(x), jnp.asarray(y), jnp.asarray(1.0 - y)


def _auc(scores, y):
    order = np.argsort(scores)
    ranks = np.empty(len(scores))
    ranks[order] = np.arange(1, len(scores) + 1)
    n_pos, n_neg = y.sum(), (1 - y).sum()
    return (ranks[y == 1].sum() - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg)


@pytest.mark.parametrize("loss_name", list(losses.LOSSES))
def test_loss_decreases_and_auc_improves(loss_name):
    mlp = mm.MODELS["mlp"]
    spec = losses.LOSSES[loss_name]
    step = jax.jit(train.make_train_step(mlp, spec))
    state = train.make_init(mlp, spec)(jnp.uint32(0))
    x, p, q = _separable_batch(128, 0.3)
    first = last = None
    for i in range(60):
        state, loss, scores = step(state, x, p, q, jnp.float32(0.1))
        if i == 0:
            first = float(loss)
            auc0 = _auc(np.asarray(scores), np.asarray(p))
        last = float(loss)
    auc1 = _auc(np.asarray(scores), np.asarray(p))
    assert np.isfinite(last)
    assert last < first, (loss_name, first, last)
    assert auc1 > max(0.8, auc0), (loss_name, auc0, auc1)


@pytest.mark.parametrize("loss_name", ["hinge", "logistic"])
def test_padding_mask_ignored_in_training(loss_name):
    """A padded batch must produce the same step as the unpadded one."""
    mlp = mm.MODELS["mlp"]
    spec = losses.LOSSES[loss_name]
    step = jax.jit(train.make_train_step(mlp, spec))
    state = train.make_init(mlp, spec)(jnp.uint32(1))
    x, p, q = _separable_batch(50, 0.3, seed=3)
    pad = 14
    x_pad = jnp.concatenate([x, jnp.zeros((pad, 64))])
    p_pad = jnp.concatenate([p, jnp.zeros(pad)])
    q_pad = jnp.concatenate([q, jnp.zeros(pad)])
    s1, l1, _ = step(state, x, p, q, jnp.float32(0.05))
    s2, l2, _ = step(state, x_pad, p_pad, q_pad, jnp.float32(0.05))
    np.testing.assert_allclose(l1, l2, rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(s1), jax.tree_util.tree_leaves(s2)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)


def test_sgd_momentum_update_rule():
    opt = optim.SGDMomentum(momentum=0.5)
    params = {"w": jnp.asarray([1.0, 2.0])}
    state = opt.init(params)
    grads = {"w": jnp.asarray([0.1, -0.2])}
    p1, s1 = opt.update(grads, state, params, 0.1)
    np.testing.assert_allclose(p1["w"], [1.0 - 0.01, 2.0 + 0.02], rtol=1e-6)
    p2, s2 = opt.update(grads, s1, p1, 0.1)
    # v2 = 0.5 * 0.1 + 0.1 = 0.15
    np.testing.assert_allclose(s2["w"], [0.15, -0.3], rtol=1e-6)


def test_pesg_ascends_alpha_and_clips():
    opt = optim.PESG(momentum=0.0, gamma=0.0)
    params = {"w": jnp.asarray([1.0]), "aucm_aux": jnp.asarray([0.2, 0.3, 0.5])}
    state = opt.init(params)
    grads = {"w": jnp.asarray([1.0]), "aucm_aux": jnp.asarray([1.0, 1.0, 1.0])}
    p1, _ = opt.update(grads, state, params, 0.1)
    np.testing.assert_allclose(p1["w"], [0.9], rtol=1e-6)  # descent
    np.testing.assert_allclose(p1["aucm_aux"][0], 0.1, rtol=1e-5)  # descent a
    np.testing.assert_allclose(p1["aucm_aux"][2], 0.6, rtol=1e-5)  # ASCENT alpha
    # clipping: drive alpha negative
    params2 = {"w": jnp.asarray([1.0]), "aucm_aux": jnp.asarray([0.0, 0.0, 0.01])}
    grads2 = {"w": jnp.asarray([0.0]), "aucm_aux": jnp.asarray([0.0, 0.0, -1.0])}
    p2, _ = opt.update(grads2, opt.init(params2), params2, 0.1)
    assert float(p2["aucm_aux"][2]) == 0.0


def test_pesg_weight_decay_only_on_weights():
    opt = optim.PESG(momentum=0.0, gamma=0.1)
    params = {"w": jnp.asarray([1.0]), "aucm_aux": jnp.asarray([1.0, 1.0, 0.0])}
    zero = {"w": jnp.asarray([0.0]), "aucm_aux": jnp.asarray([0.0, 0.0, 0.0])}
    p1, _ = opt.update(zero, opt.init(params), params, 1.0)
    np.testing.assert_allclose(p1["w"], [0.9], rtol=1e-6)  # decayed
    np.testing.assert_allclose(p1["aucm_aux"][:2], [1.0, 1.0], rtol=1e-6)  # not


def test_loss_eval_matches_direct_loss():
    spec = losses.LOSSES["hinge"]
    fn = train.make_loss_eval(spec)
    rng = np.random.default_rng(0)
    s = jnp.asarray(rng.normal(0, 1, 256).astype(np.float32))
    y = jnp.asarray((rng.random(256) < 0.2).astype(np.float32))
    np.testing.assert_allclose(
        fn(s, y, 1 - y), losses.allpairs_squared_hinge(s, y, 1 - y), rtol=1e-6
    )


def test_loss_eval_rejects_aucm():
    with pytest.raises(ValueError):
        train.make_loss_eval(losses.LOSSES["aucm"])


def test_init_state_structure():
    mlp = mm.MODELS["mlp"]
    state = train.make_init(mlp, losses.LOSSES["aucm"])(jnp.uint32(0))
    params, opt_state = state
    assert "aucm_aux" in params
    assert params["aucm_aux"].shape == (3,)
    # momentum mirrors params exactly
    assert jax.tree_util.tree_structure(params) == jax.tree_util.tree_structure(
        opt_state
    )
