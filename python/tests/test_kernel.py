"""Kernel vs oracle: the CORE correctness signal of the stack.

Three implementations of each all-pairs loss must agree to float32
tolerance on loss AND gradient:

  naive O(n^2) pairwise matrix   (paper eq. 2, ground truth)
  functional jnp sort+cumsum     (paper Algorithms 1 & 2, second oracle)
  Pallas kernels                 (what ships in the AOT artifacts)

plus the Pallas gradient must agree with jax autodiff of the naive loss.
Hypothesis drives shapes, margins, imbalance, ties, and padding.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    hinge_loss_and_grad,
    square_loss_and_grad,
    ref,
)

jax.config.update("jax_platform_name", "cpu")

RTOL = 2e-3
ATOL = 2e-3


def _random_case(seed, n, pos_frac, scale=2.0, quantize=False):
    rng = np.random.default_rng(seed)
    s = rng.normal(0.0, scale, n).astype(np.float32)
    if quantize:  # force many exact ties
        s = np.round(s * 2.0) / 2.0
    y = (rng.random(n) < pos_frac).astype(np.float32)
    return jnp.asarray(s), jnp.asarray(y), jnp.asarray(1.0 - y)


def _check_all(s, p, q, margin):
    """Assert 3-way agreement for both losses, loss + grad."""
    # squared hinge
    l_naive = ref.naive_squared_hinge(s, p, q, margin)
    l_func = ref.functional_squared_hinge(s, p, q, margin)
    l_pal, g_pal = hinge_loss_and_grad(s, p, q, margin)
    g_naive = ref.naive_squared_hinge_grad(s, p, q, margin)
    g_func = ref.functional_squared_hinge_grad(s, p, q, margin)
    scale = max(1.0, float(l_naive))
    np.testing.assert_allclose(l_func, l_naive, rtol=RTOL, atol=ATOL * scale)
    np.testing.assert_allclose(l_pal, l_naive, rtol=RTOL, atol=ATOL * scale)
    gscale = max(1.0, float(jnp.max(jnp.abs(g_naive))))
    np.testing.assert_allclose(g_func, g_naive, rtol=RTOL, atol=ATOL * gscale)
    np.testing.assert_allclose(g_pal, g_naive, rtol=RTOL, atol=ATOL * gscale)
    # square
    l_naive = ref.naive_square(s, p, q, margin)
    l_func = ref.functional_square(s, p, q, margin)
    l_pal, g_pal = square_loss_and_grad(s, p, q, margin)
    g_naive = ref.naive_square_grad(s, p, q, margin)
    g_func = ref.functional_square_grad(s, p, q, margin)
    scale = max(1.0, float(l_naive))
    np.testing.assert_allclose(l_func, l_naive, rtol=RTOL, atol=ATOL * scale)
    np.testing.assert_allclose(l_pal, l_naive, rtol=RTOL, atol=ATOL * scale)
    gscale = max(1.0, float(jnp.max(jnp.abs(g_naive))))
    np.testing.assert_allclose(g_func, g_naive, rtol=RTOL, atol=ATOL * gscale)
    np.testing.assert_allclose(g_pal, g_naive, rtol=RTOL, atol=ATOL * gscale)


# ---------------------------------------------------------------------------
# Hypothesis sweep: shapes x margins x imbalance x tie-density.
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(2, 600),
    pos_frac=st.sampled_from([0.01, 0.1, 0.3, 0.5, 0.9]),
    margin=st.sampled_from([0.0, 0.5, 1.0, 3.0]),
    quantize=st.booleans(),
)
def test_hypothesis_agreement(seed, n, pos_frac, margin, quantize):
    s, p, q = _random_case(seed, n, pos_frac, quantize=quantize)
    _check_all(s, p, q, margin)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_large_n_crosses_blocks(seed):
    """n > DEFAULT_BLOCK so the carry actually crosses grid steps."""
    s, p, q = _random_case(seed, 4096 + 37, 0.2)
    _check_all(s, p, q, 1.0)


# ---------------------------------------------------------------------------
# Edge cases.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [2, 3, 7, 8, 9, 1023, 1024, 1025])
def test_block_boundaries(n):
    """Sizes straddling the Pallas block size (padding path)."""
    s, p, q = _random_case(n, n, 0.4)
    _check_all(s, p, q, 1.0)


@pytest.mark.parametrize("which", ["all_pos", "all_neg"])
def test_single_class_is_zero(which):
    s = jnp.linspace(-2, 2, 50)
    ones, zeros = jnp.ones(50), jnp.zeros(50)
    p, q = (ones, zeros) if which == "all_pos" else (zeros, ones)
    l, g = hinge_loss_and_grad(s, p, q, 1.0)
    assert float(l) == 0.0
    np.testing.assert_allclose(g, 0.0)
    l, g = square_loss_and_grad(s, p, q, 1.0)
    assert float(l) == 0.0
    np.testing.assert_allclose(g, 0.0)


def test_single_positive_extreme_imbalance():
    rng = np.random.default_rng(7)
    s = jnp.asarray(rng.normal(0, 1, 200).astype(np.float32))
    p = jnp.zeros(200).at[13].set(1.0)
    q = 1.0 - p
    _check_all(s, p, q, 1.0)


def test_padding_rows_are_ignored():
    """Rows with both masks zero must not change loss or gradient."""
    s, p, q = _random_case(3, 100, 0.3)
    s_pad = jnp.concatenate([s, jnp.asarray([100.0, -100.0, 0.0])])
    p_pad = jnp.concatenate([p, jnp.zeros(3)])
    q_pad = jnp.concatenate([q, jnp.zeros(3)])
    l0, g0 = hinge_loss_and_grad(s, p, q, 1.0)
    l1, g1 = hinge_loss_and_grad(s_pad, p_pad, q_pad, 1.0)
    np.testing.assert_allclose(l0, l1, rtol=1e-5)
    np.testing.assert_allclose(g0, g1[:100], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(g1[100:], 0.0)
    l0, g0 = square_loss_and_grad(s, p, q, 1.0)
    l1, g1 = square_loss_and_grad(s_pad, p_pad, q_pad, 1.0)
    np.testing.assert_allclose(l0, l1, rtol=1e-5)
    np.testing.assert_allclose(g1[100:], 0.0)


def test_perfect_separation_hinge_zero_beyond_margin():
    """All positives above all negatives by > m  =>  hinge loss exactly 0."""
    neg = jnp.linspace(-3.0, -2.0, 40)
    pos = jnp.linspace(2.0, 3.0, 10)
    s = jnp.concatenate([neg, pos])
    p = jnp.concatenate([jnp.zeros(40), jnp.ones(10)])
    q = 1.0 - p
    l, g = hinge_loss_and_grad(s, p, q, 1.0)
    assert float(l) == 0.0
    np.testing.assert_allclose(g, 0.0)


def test_ties_exactly_at_margin_are_zero():
    """A pair with yhat_j - yhat_k == m sits exactly on the hinge: 0 loss."""
    s = jnp.asarray([0.0, 1.0], jnp.float32)  # neg at 0, pos at 1, m = 1
    p = jnp.asarray([0.0, 1.0])
    q = jnp.asarray([1.0, 0.0])
    l, g = hinge_loss_and_grad(s, p, q, 1.0)
    np.testing.assert_allclose(l, 0.0, atol=1e-6)
    np.testing.assert_allclose(g, 0.0, atol=1e-6)


def test_two_examples_hand_computed():
    """n = 2, one pair: L = (m - (yj - yk))^2 = (1 - (0.3 - 0.8))^2."""
    s = jnp.asarray([0.8, 0.3], jnp.float32)  # neg first
    p = jnp.asarray([0.0, 1.0])
    q = jnp.asarray([1.0, 0.0])
    expected = (1.0 - (0.3 - 0.8)) ** 2
    l, _ = hinge_loss_and_grad(s, p, q, 1.0)
    np.testing.assert_allclose(l, expected, rtol=1e-6)
    l, _ = square_loss_and_grad(s, p, q, 1.0)
    np.testing.assert_allclose(l, expected, rtol=1e-6)


def test_grad_matches_autodiff_of_naive():
    """Closed-form kernel gradient == jax.grad of the naive double sum."""
    s, p, q = _random_case(11, 257, 0.25)
    for m in (0.0, 1.0):
        g_auto = jax.grad(lambda s_: ref.naive_squared_hinge(s_, p, q, m))(s)
        _, g_pal = hinge_loss_and_grad(s, p, q, m)
        np.testing.assert_allclose(g_pal, g_auto, rtol=1e-3, atol=1e-3)
        g_auto = jax.grad(lambda s_: ref.naive_square(s_, p, q, m))(s)
        _, g_pal = square_loss_and_grad(s, p, q, m)
        np.testing.assert_allclose(g_pal, g_auto, rtol=1e-3, atol=1e-3)


def test_monotone_improvement_decreases_hinge():
    """Raising a positive score (or lowering a negative) never increases L."""
    s, p, q = _random_case(5, 64, 0.3)
    l0, _ = hinge_loss_and_grad(s, p, q, 1.0)
    j = int(jnp.argmax(p))
    s_up = s.at[j].add(0.5)
    l1, _ = hinge_loss_and_grad(s_up, p, q, 1.0)
    assert float(l1) <= float(l0) + 1e-5


def test_jit_and_block_size_invariance():
    s, p, q = _random_case(21, 777, 0.15)
    l_ref = ref.naive_squared_hinge(s, p, q, 1.0)
    for block in (8, 64, 1024):
        l, _ = hinge_loss_and_grad(s, p, q, 1.0, block=block)
        np.testing.assert_allclose(l, l_ref, rtol=1e-4)
    jitted = jax.jit(lambda *a: hinge_loss_and_grad(*a, 1.0))
    l, _ = jitted(s, p, q)
    np.testing.assert_allclose(l, l_ref, rtol=1e-4)
