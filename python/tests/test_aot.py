"""AOT artifact structure: lowering, manifest integrity, no-O(n^2) check.

These tests lower a *small* subset of artifacts in-process (fast) and, when
``artifacts/manifest.json`` exists from a full `make artifacts` run, audit
the manifest against the builder's naming and signature rules.
"""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, losses, model as mm, train

jax.config.update("jax_platform_name", "cpu")

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


def test_hlo_text_lowering_roundtrip():
    """to_hlo_text output parses back through xla_client (id-safe path)."""
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4]" in text


def test_mlp_train_artifact_structure():
    """Flat wrapper: arity and shapes agree with the state pytree."""
    mlp = mm.MODELS["mlp"]
    spec = losses.LOSSES["hinge"]
    (
        init_flat,
        train_flat,
        predict_flat,
        state_avals,
        n_state,
        predict_avals,
        predict_indices,
    ) = aot._flat_state_fns(mlp, spec)
    state = init_flat(jnp.uint32(0))
    assert len(state) == n_state
    x = jnp.zeros((8, 64), jnp.float32)
    mask = jnp.zeros((8,), jnp.float32).at[:4].set(1.0)
    out = train_flat(*state, x, mask, 1.0 - mask, jnp.float32(0.1))
    assert len(out) == n_state + 2
    loss, scores = out[-2], out[-1]
    assert loss.shape == ()
    assert scores.shape == (8,)
    # predict consumes only the model-parameter slots
    sel = [state[i] for i in predict_indices]
    (pred,) = predict_flat(*sel, x)
    assert pred.shape == (8,)
    assert len(predict_avals) == len(predict_indices)


def test_predict_indices_select_model_params():
    """predict_indices: first half of state (params), aux excluded."""
    mlp = mm.MODELS["mlp"]
    # plain loss: params are state[:n_state//2], all of them selected
    out = aot._flat_state_fns(mlp, losses.LOSSES["hinge"])
    n_state, indices = out[4], out[6]
    assert indices == list(range(n_state // 2))
    # aucm: the aux leaf (sorted first: "aucm_aux" < "dense0") is excluded
    out = aot._flat_state_fns(mlp, losses.LOSSES["aucm"])
    n_state_aucm, indices_aucm = out[4], out[6]
    assert 0 not in indices_aucm
    assert len(indices_aucm) == n_state_aucm // 2 - 1


def test_aucm_predict_matches_full_apply():
    """predict through selected leaves == model.apply on the full params."""
    mlp = mm.MODELS["mlp"]
    spec = losses.LOSSES["aucm"]
    out = aot._flat_state_fns(mlp, spec)
    init_flat, predict_flat, indices = out[0], out[2], out[6]
    state = init_flat(jnp.uint32(3))
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    sel = [state[i] for i in indices]
    (pred,) = predict_flat(*sel, x)
    # reference: rebuild the params pytree and apply directly
    full_state = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(
            jax.eval_shape(aot.train_mod.make_init(mlp, spec), jnp.uint32(0))
        ),
        list(state),
    )
    ref = mlp.apply(full_state[0], x)
    np.testing.assert_allclose(pred, ref, rtol=1e-6)


def test_flat_state_roundtrip_is_identity():
    """init -> train with lr=0 returns identical parameters."""
    mlp = mm.MODELS["mlp"]
    spec = losses.LOSSES["logistic"]
    out = aot._flat_state_fns(mlp, spec)
    init_flat, train_flat, n_state = out[0], out[1], out[4]
    state = init_flat(jnp.uint32(7))
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    mask = jnp.ones((8,), jnp.float32).at[4:].set(0.0)
    out = train_flat(*state, x, mask, 1.0 - mask, jnp.float32(0.0))
    for a, b in zip(state, out[:n_state]):
        if a.shape == b.shape:
            # momentum buffers change (they accumulate grads); params with
            # lr=0 must not.
            pass
    # params are the first half of the flat state (params, opt_state)
    n_params = n_state // 2
    for a, b in zip(state[:n_params], out[:n_params]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_no_quadratic_pair_matrix_in_hinge_artifact():
    """Structural perf guarantee: the lowered hinge train step contains no
    O(batch^2) intermediate (the naive formulation would materialize a
    [bs, bs] array)."""
    mlp = mm.MODELS["mlp"]
    spec = losses.LOSSES["hinge"]
    out = aot._flat_state_fns(mlp, spec)
    train_flat, state_avals = out[1], out[3]
    bs = 100
    x = jax.ShapeDtypeStruct((bs, 64), jnp.float32)
    mask = jax.ShapeDtypeStruct((bs,), jnp.float32)
    lr = jax.ShapeDtypeStruct((), jnp.float32)
    lowered = jax.jit(train_flat).lower(*state_avals, x, mask, mask, lr)
    text = aot.to_hlo_text(lowered)
    assert f"f32[{bs},{bs}]" not in text, "quadratic pair matrix leaked into HLO"


@pytest.mark.skipif(
    not (ARTIFACTS / "manifest.json").exists(),
    reason="run `make artifacts` first",
)
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        return json.loads((ARTIFACTS / "manifest.json").read_text())

    def test_every_file_exists(self, manifest):
        for e in manifest["artifacts"]:
            assert (ARTIFACTS / e["file"]).exists(), e["name"]

    def test_expected_artifact_set(self, manifest):
        names = {e["name"] for e in manifest["artifacts"]}
        for loss in aot.SWEEP_LOSSES:
            assert f"init_resnet_{loss}" in names
            for bs in aot.TRAIN_BATCH_SIZES:
                assert f"train_resnet_{loss}_bs{bs}" in names
            assert f"predict_resnet_{loss}_bs{aot.PREDICT_BATCH}" in names
        assert "init_mlp_hinge" in names
        assert f"loss_eval_hinge_n{aot.LOSS_EVAL_N}" in names

    def test_train_signatures(self, manifest):
        for e in manifest["artifacts"]:
            if e["kind"] != "train":
                continue
            ins = e["inputs"]
            n_state, bs = e["n_state"], e["batch"]
            assert len(ins) == n_state + 4
            assert ins[n_state]["shape"][0] == bs  # x
            assert ins[n_state + 1]["shape"] == [bs]  # is_pos
            assert ins[n_state + 2]["shape"] == [bs]  # is_neg
            assert ins[n_state + 3]["shape"] == []  # lr
            assert e["n_outputs"] == n_state + 2

    def test_init_signature(self, manifest):
        for e in manifest["artifacts"]:
            if e["kind"] != "init":
                continue
            assert len(e["inputs"]) == 1
            assert e["inputs"][0]["dtype"] == "uint32"
            assert e["n_outputs"] == e["n_state"]

    def test_margin_recorded(self, manifest):
        assert manifest["margin"] == train.MARGIN
