"""L2 loss wrappers: custom-VJP correctness, normalization, AUCM algebra."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import losses
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _case(seed=0, n=128, pos_frac=0.3):
    rng = np.random.default_rng(seed)
    s = jnp.asarray(rng.normal(0, 1, n).astype(np.float32))
    y = jnp.asarray((rng.random(n) < pos_frac).astype(np.float32))
    return s, y, 1.0 - y


def test_hinge_wrapper_matches_normalized_naive():
    s, p, q = _case()
    expected = ref.naive_squared_hinge(s, p, q, 1.0) / ref.pair_count(p, q)
    got = losses.allpairs_squared_hinge(s, p, q)
    np.testing.assert_allclose(got, expected, rtol=1e-4)


def test_square_wrapper_matches_normalized_naive():
    s, p, q = _case(1)
    expected = ref.naive_square(s, p, q, 1.0) / ref.pair_count(p, q)
    got = losses.allpairs_square_loss(s, p, q)
    np.testing.assert_allclose(got, expected, rtol=1e-4)


@pytest.mark.parametrize("name", ["hinge", "square"])
def test_custom_vjp_matches_autodiff_of_naive(name):
    s, p, q = _case(2, 200, 0.2)
    pairwise = losses.LOSSES[name].fn
    naive = losses.naive_squared_hinge if name == "hinge" else losses.naive_square
    g_fast = jax.grad(lambda s_: pairwise(s_, p, q))(s)
    g_ref = jax.grad(lambda s_: naive(s_, p, q))(s)
    np.testing.assert_allclose(g_fast, g_ref, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("name", ["hinge", "square"])
def test_vjp_scales_with_cotangent(name):
    """bwd must multiply by the upstream cotangent g."""
    s, p, q = _case(3, 64, 0.4)
    pairwise = losses.LOSSES[name].fn
    g1 = jax.grad(lambda s_: pairwise(s_, p, q))(s)
    g3 = jax.grad(lambda s_: 3.0 * pairwise(s_, p, q))(s)
    np.testing.assert_allclose(g3, 3.0 * g1, rtol=1e-5)


def test_grad_through_scores_chain():
    """Gradient flows through a model-like transformation of scores."""
    s, p, q = _case(4, 50, 0.3)
    w = jnp.float32(0.7)

    def f(w_):
        return losses.allpairs_squared_hinge(jax.nn.sigmoid(w_ * s), p, q)

    g = jax.grad(f)(w)
    # finite difference check
    eps = 1e-3
    fd = (f(w + eps) - f(w - eps)) / (2 * eps)
    np.testing.assert_allclose(g, fd, rtol=5e-2, atol=1e-4)


def test_normalization_batchsize_invariant():
    """Duplicating the batch leaves the normalized loss unchanged."""
    s, p, q = _case(5, 80, 0.25)
    l1 = losses.allpairs_squared_hinge(s, p, q)
    s2, p2, q2 = jnp.tile(s, 2), jnp.tile(p, 2), jnp.tile(q, 2)
    l2 = losses.allpairs_squared_hinge(s2, p2, q2)
    np.testing.assert_allclose(l1, l2, rtol=1e-4)


def test_single_class_batch_is_finite():
    s = jnp.linspace(0.1, 0.9, 16)
    zero = jnp.zeros(16)
    one = jnp.ones(16)
    assert jnp.isfinite(losses.allpairs_squared_hinge(s, one, zero))
    assert float(losses.allpairs_squared_hinge(s, one, zero)) == 0.0
    assert jnp.isfinite(losses.logistic(s, one, zero))


def test_logistic_matches_bce():
    s, p, q = _case(6, 100, 0.5)
    s = jax.nn.sigmoid(s)  # probabilities
    expected = -(p * jnp.log(s) + q * jnp.log(1 - s)).mean()
    got = losses.logistic(s, p, q)
    np.testing.assert_allclose(got, expected, rtol=1e-4)


# ---------------------------------------------------------------------------
# AUCM (LIBAUC baseline)
# ---------------------------------------------------------------------------


def test_aucm_value_hand_computed():
    s = jnp.asarray([0.9, 0.8, 0.2, 0.1], jnp.float32)
    p = jnp.asarray([1.0, 1.0, 0.0, 0.0])
    q = 1.0 - p
    aux = jnp.asarray([0.5, 0.3, 0.2], jnp.float32)  # a, b, alpha
    mean_pos, mean_neg = 0.85, 0.15
    var_pos = np.mean([(0.9 - 0.5) ** 2, (0.8 - 0.5) ** 2])
    var_neg = np.mean([(0.2 - 0.3) ** 2, (0.1 - 0.3) ** 2])
    expected = var_pos + var_neg + 2 * 0.2 * (1.0 + mean_neg - mean_pos) - 0.04
    got = losses.aucm(s, p, q, aux, 1.0)
    np.testing.assert_allclose(got, expected, rtol=1e-5)


def test_aucm_optimal_a_b_are_class_means():
    """At the saddle point a* = E+[h], b* = E-[h] (grad wrt a,b is zero)."""
    s, p, q = _case(7, 64, 0.4)
    mean_pos = float(jnp.sum(p * s) / jnp.sum(p))
    mean_neg = float(jnp.sum(q * s) / jnp.sum(q))
    aux = jnp.asarray([mean_pos, mean_neg, 0.1], jnp.float32)
    g = jax.grad(lambda a_: losses.aucm(s, p, q, a_, 1.0))(aux)
    np.testing.assert_allclose(g[0], 0.0, atol=1e-5)
    np.testing.assert_allclose(g[1], 0.0, atol=1e-5)


def test_aucm_alpha_gradient_sign():
    """d L / d alpha = 2 (m + E-[h] - E+[h]) - 2 alpha."""
    s, p, q = _case(8, 64, 0.3)
    aux = jnp.asarray([0.0, 0.0, 0.5], jnp.float32)
    mean_pos = jnp.sum(p * s) / jnp.sum(p)
    mean_neg = jnp.sum(q * s) / jnp.sum(q)
    expected = 2.0 * (1.0 + mean_neg - mean_pos) - 2.0 * 0.5
    g = jax.grad(lambda a_: losses.aucm(s, p, q, a_, 1.0))(aux)
    np.testing.assert_allclose(g[2], expected, rtol=1e-4)


def test_registry_complete():
    assert set(losses.LOSSES) == {"hinge", "square", "logistic", "aucm"}
    assert losses.LOSSES["hinge"].pairwise
    assert losses.LOSSES["aucm"].needs_aux
    assert not losses.LOSSES["logistic"].pairwise
